"""Paper §6.4: ρ = makespan / area-lower-bound.

§6.4.1 (Rodinia fixture, paper: 1.22) and Table 4 (synthetic, WideTimes,
ρ vs n for the three scaling mixes; paper: 1.20-1.23 at n=10 down to
1.01-1.02 at n=35)."""

import numpy as np

from repro.core.device_spec import A30, A100
from repro.core.far import rho, schedule_batch
from repro.core.rodinia import rodinia_tasks
from repro.core.synth import generate_tasks, workload

from benchmarks.common import Rows


def run(reps: int = 100) -> Rows:
    rows = Rows(
        "Table 4 / §6.4: rho vs optimum lower bound (A100, WideTimes)",
        ["config", "n", "rho_mean", "paper"],
    )
    tasks = rodinia_tasks(A100)
    r = schedule_batch(tasks, A100)
    rows.add("rodinia-fixture(16)", 16, rho(r, tasks), 1.22)
    t30 = rodinia_tasks(A30)
    r30 = schedule_batch(t30, A30)
    rows.add("rodinia-fixture/A30", 16, rho(r30, t30), "~1.01")

    paper = {
        ("poor", 10): 1.23, ("poor", 15): 1.08, ("poor", 20): 1.04,
        ("poor", 25): 1.03, ("poor", 30): 1.02, ("poor", 35): 1.02,
        ("mixed", 10): 1.20, ("mixed", 15): 1.08, ("mixed", 20): 1.04,
        ("mixed", 25): 1.03, ("mixed", 30): 1.02, ("mixed", 35): 1.02,
        ("good", 10): 1.21, ("good", 15): 1.07, ("good", 20): 1.05,
        ("good", 25): 1.03, ("good", 30): 1.02, ("good", 35): 1.01,
    }
    for scaling in ("poor", "mixed", "good"):
        cfg = workload(scaling, "wide", A100)
        for n in (10, 15, 20, 25, 30, 35):
            vals = []
            for seed in range(reps):
                ts = generate_tasks(n, A100, cfg, seed=seed)
                vals.append(rho(schedule_batch(ts, A100), ts))
            rows.add(f"{scaling}Scaling", n, float(np.mean(vals)),
                     paper[(scaling, n)])
    return rows
