"""Paper Table 1: instance create/destroy times per device and size."""

from repro.core.device_spec import A30, A100, H100, TPU_POD_256

from benchmarks.common import Rows


def run(reps: int = 0) -> Rows:
    rows = Rows(
        "Table 1: reconfiguration times (s)",
        ["device", "size", "create", "destroy"],
    )
    for spec in (A30, A100, H100, TPU_POD_256):
        for s in spec.sizes:
            rows.add(spec.name, s, spec.t_create[s], spec.t_destroy[s])
    return rows
