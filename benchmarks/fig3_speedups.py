"""Paper Fig. 2/3 + Fig. 11: task speedup profiles — the Rodinia-style
fixture and a synthetic sample (verifies the generator reproduces the
described regimes: super-linear memory-bound, near-linear, saturating)."""

from repro.core.device_spec import A100
from repro.core.rodinia import rodinia_tasks
from repro.core.synth import generate_tasks, workload

from benchmarks.common import Rows


def run(reps: int = 0) -> Rows:
    rows = Rows(
        "Fig 3/11: speedup vs slices (A100)",
        ["task", "sp(2)", "sp(3)", "sp(4)", "sp(7)", "regime"],
    )
    for t in rodinia_tasks(A100)[:8]:
        sp = {s: t.times[1] / t.times[s] for s in (2, 3, 4, 7)}
        regime = (
            "super-linear" if sp[7] > 7 else
            "saturating" if sp[7] < 3 else "near-linear"
        )
        rows.add(t.name, sp[2], sp[3], sp[4], sp[7], regime)
    cfg = workload("mixed", "wide", A100)
    n_super = 0
    tasks = generate_tasks(10, A100, cfg, seed=0)
    for t in tasks:
        sp = {s: t.times[1] / t.times[s] for s in (2, 3, 4, 7)}
        if sp[2] > 2.0:
            n_super += 1
        rows.add(f"synth{t.id}", sp[2], sp[3], sp[4], sp[7], "synthetic")
    assert n_super >= 1, "generator lost the super-linear regime"
    return rows
