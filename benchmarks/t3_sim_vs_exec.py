"""Paper Table 3: simulated vs executed task end times (A30, 9 kernels).

The paper compares FAR's simulated schedule against a real-GPU run and
finds ≤2.25% deviation.  Our analogue executes the schedule in the
discrete-event executor with ±2% per-task duration noise (the measured
variability class) and reports the per-kernel end-time deviation."""

from repro.core.device_spec import A30
from repro.core.far import schedule_batch
from repro.core.rodinia import TABLE3_KERNELS, rodinia_tasks
from repro.runtime.executor import SimExecutor

from benchmarks.common import Rows


def run(reps: int = 0) -> Rows:
    tasks = rodinia_tasks(A30, TABLE3_KERNELS)
    far = schedule_batch(tasks, A30)
    result = SimExecutor(duration_noise=0.02, seed=42).run(far.schedule)
    rows = Rows(
        "Table 3: simulated vs executed end times (A30, ±2% noise)",
        ["kernel", "sim_end", "exec_end", "deviation_%"],
    )
    sim_ends = {it.task.id: it.end for it in far.schedule.items}
    max_dev = 0.0
    for t in sorted(tasks, key=lambda t: sim_ends[t.id]):
        sim = sim_ends[t.id]
        real = result.finished[t.id]
        dev = (real / sim - 1.0) * 100
        max_dev = max(max_dev, abs(dev))
        rows.add(t.name, sim, real, dev)
    rows.add("(max |dev|)", "", "", max_dev)
    return rows
