"""Gradient compression: quantisation, error feedback, int8 ring."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.compression import (
    dequantize_int8,
    ef_compress,
    ef_init,
    quantize_int8,
)


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.key(0), (256,), jnp.float32) * 3.0
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    """Sum of compressed gradients converges to the sum of raw gradients."""
    rng = np.random.default_rng(0)
    grads = [jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
             for _ in range(50)]
    err = ef_init({"g": grads[0]})
    total_raw = jnp.zeros((64,))
    total_comp = jnp.zeros((64,))
    for g in grads:
        comp, err = ef_compress({"g": g}, err)
        total_raw += g
        total_comp += comp["g"]
    # residual error stays bounded by one quantisation step, it never grows
    resid = jnp.max(jnp.abs(total_raw - total_comp))
    scales = [quantize_int8(g)[1] for g in grads]
    assert float(resid) < 3 * float(max(scales))


_RING_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
import numpy as np
from functools import partial
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.parallel.compression import ring_allreduce_int8

mesh = jax.make_mesh((8,), ("pod",))
x = jax.random.normal(jax.random.key(0), (8, 64), jnp.float32)

@partial(shard_map, mesh=mesh, in_specs=P("pod", None),
         out_specs=P("pod", None))
def ring(v):
    flat = v.reshape(-1)
    out = ring_allreduce_int8(flat, "pod", 8)
    return out.reshape(v.shape)

got = ring(x)
want = jnp.broadcast_to(jnp.sum(x, axis=0, keepdims=True), x.shape)
rel = float(jnp.max(jnp.abs(got - want)) / (jnp.max(jnp.abs(want)) + 1e-9))
assert rel < 0.05, rel
print("RING_OK", rel)
"""


def test_int8_ring_allreduce_matches_psum():
    """Run on 8 virtual devices in a subprocess (tests keep 1 device)."""
    out = subprocess.run(
        [sys.executable, "-c", _RING_SCRIPT, "src"],
        capture_output=True, text=True, timeout=300, cwd=".",
    )
    assert "RING_OK" in out.stdout, out.stdout + out.stderr


def test_train_step_with_compression_converges():
    from repro.launch.train import train

    out = train("gemma-2b", steps=30, batch=8, seq=64, smoke=True,
                compress_grads=True, log_fn=lambda *_: None)
    # compressed training still converges (error feedback at work)
    head = float(np.mean(out["losses"][:5]))
    tail = float(np.mean(out["losses"][-5:]))
    assert tail < head - 0.1, (head, tail)
