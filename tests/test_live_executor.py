"""Algorithm-3 live executor: real training jobs on sub-device groups,
concurrent across disjoint instances (8 virtual devices, subprocess)."""

import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, sys.argv[1])
import jax
from repro.core.device_spec import A30
from repro.core.problem import Task
from repro.core.far import schedule_batch
from repro.runtime.live import run_live
from repro.launch.train import train

tasks = [
    Task(0, {1: 3.0, 2: 1.7, 4: 1.0}, "jobA"),
    Task(1, {1: 2.0, 2: 1.2, 4: 0.8}, "jobB"),
    Task(2, {1: 1.0, 2: 0.8, 4: 0.7}, "jobC"),
    Task(3, {1: 1.5, 2: 0.9, 4: 0.75}, "jobD"),
]
far = schedule_batch(tasks, A30)
steps = {0: 4, 1: 3, 2: 2, 3: 2}

def task_fn(tid, mesh):
    out = train("gemma-2b", steps=steps[tid], batch=mesh.devices.size,
                seq=32, smoke=True, mesh=mesh, log_every=1000,
                log_fn=lambda *_: None)
    return {"loss": out["last_loss"], "ndev": int(mesh.devices.size)}

recs = run_live(far.assignment, A30, task_fn)
assert len(recs) == 4
assert all(r.payload["loss"] > 0 for r in recs)
# instance sizes follow the FAR molding: devices = 2 * slices (8 devs / 4)
sizes = {r.task_id: r.payload["ndev"] for r in recs}
by_node = far.assignment.node_tasks
for key, tids in by_node.items():
    for tid in tids:
        assert sizes[tid] == 2 * key[2], (tid, sizes[tid], key)
# tasks on disjoint instances overlap in wall time (concurrency check):
# find two placements on disjoint nodes and assert their spans intersect
import itertools
spans = {r.task_id: (r.start, r.end) for r in recs}
nodes = {tid: key for key, tids in by_node.items() for tid in tids}
overlap = False
for a, b in itertools.combinations(spans, 2):
    ka, kb = nodes[a], nodes[b]
    cells_a = set(range(ka[1], ka[1] + ka[3]))
    cells_b = set(range(kb[1], kb[1] + kb[3]))
    if cells_a & cells_b:
        continue
    (s1, e1), (s2, e2) = spans[a], spans[b]
    if s1 < e2 and s2 < e1:
        overlap = True
assert overlap, "disjoint instances never ran concurrently"
print("LIVE_OK")
"""


def test_live_executor_runs_far_tree_concurrently():
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT, "src"],
        capture_output=True, text=True, timeout=900, cwd=".",
    )
    assert "LIVE_OK" in out.stdout, out.stdout[-1500:] + out.stderr[-3000:]
