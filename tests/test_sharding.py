"""Sharding rules: every FULL config's param/cache spec must divide evenly
on the production meshes (this is what makes the 40-cell dry-run pass)."""

import jax
import pytest

from repro.configs import ARCHS
from repro.models.config import SHAPES, shape_applicable
from repro.models.model import build_model
from repro.parallel.sharding import make_rules

SINGLE = {"data": 16, "model": 16}
MULTI = {"pod": 2, "data": 16, "model": 16}


def _check_divisible(shapes_tree, specs_tree, rules, mesh_shape, tag):
    shapes = jax.tree.leaves(shapes_tree)
    is_spec = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )
    specs = jax.tree.leaves(specs_tree, is_leaf=is_spec)
    assert len(shapes) == len(specs), tag
    for sds, spec in zip(shapes, specs):
        assert len(spec) == len(sds.shape), (tag, spec, sds.shape)
        pspec = rules.spec(*spec)
        for dim, axes in zip(sds.shape, pspec):
            if axes is None:
                continue
            axes = (axes,) if isinstance(axes, str) else axes
            total = 1
            for a in axes:
                total *= mesh_shape[a]
            assert dim % total == 0, (tag, spec, sds.shape, axes)


@pytest.mark.parametrize("mesh_shape", [SINGLE, MULTI],
                         ids=["single", "multi"])
@pytest.mark.parametrize("name", sorted(ARCHS))
def test_full_config_param_shardings_divide(name, mesh_shape):
    cfg = ARCHS[name]
    model = build_model(cfg)
    rules = make_rules(cfg, mesh_shape)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    _check_divisible(shapes, model.param_specs(), rules, mesh_shape, name)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_full_config_cache_shardings_divide(name):
    cfg = ARCHS[name]
    model = build_model(cfg)
    for shape_name in ("decode_32k", "long_500k"):
        shape = SHAPES[shape_name]
        ok, _ = shape_applicable(cfg, shape)
        if not ok:
            continue
        rules = make_rules(cfg, SINGLE, batch_size=shape.global_batch)
        cache = model.cache_shapes(shape.global_batch, shape.seq_len)
        specs = model.cache_specs(shape.global_batch, shape.seq_len)
        _check_divisible(cache, specs, rules, SINGLE,
                         f"{name}/{shape_name}")


def test_rules_fall_back_when_heads_do_not_divide():
    cfg = ARCHS["gemma-2b"]  # 8 heads on a 16-way model axis
    rules = make_rules(cfg, SINGLE)
    assert rules.rules["heads"] == ()      # attention replicated
    assert rules.rules["ff"] == ("model",)  # FFN still TP


def test_moe_ep_vs_expert_tp_selection():
    import dataclasses

    r_moon = make_rules(ARCHS["moonshot-v1-16b-a3b"], SINGLE)
    assert r_moon.rules["experts"] == ("model",)   # 64 experts: true EP
    # qwen2-moe pads 60 -> 64 experts for EP (EXPERIMENTS.md §Perf H3b)
    r_qwen = make_rules(ARCHS["qwen2-moe-a2.7b"], SINGLE)
    assert ARCHS["qwen2-moe-a2.7b"].n_experts_padded == 64
    assert r_qwen.rules["experts"] == ("model",)
    # without padding the fallback is intra-expert tensor parallelism
    unpadded = dataclasses.replace(ARCHS["qwen2-moe-a2.7b"], expert_pad=0)
    r_tp = make_rules(unpadded, SINGLE)
    assert r_tp.rules["experts"] == ()
    assert r_tp.rules["expert_ff"] == ("model",)


def test_fsdp_enabled_only_for_large_models():
    big = make_rules(ARCHS["qwen1.5-110b"], SINGLE)
    assert big.rules["embed"] == ("data",)
    small = make_rules(ARCHS["xlstm-350m"], SINGLE)
    assert small.rules["embed"] == ()
