"""Device-spec structure: valid partitions, trees, degradation."""

import pytest

from repro.core.device_spec import (
    A30, A100, H100, TPU_POD_256, TPU_SUPERPOD_512, multi_gpu,
)


def test_partition_counts_match_paper_fig1():
    assert len(A30.valid_partitions) == 5
    assert len(A100.valid_partitions) == 19
    assert len(H100.valid_partitions) == 19


def test_partitions_tile_all_slices():
    for spec in (A30, A100, TPU_POD_256):
        for p in spec.valid_partitions:
            blocked = sorted(
                (node.tree, s) for node in p for s in node.blocked
            )
            want = sorted(
                (r.tree, s) for r in spec.roots for s in r.blocked
            )
            assert blocked == want, (spec.name, p)


def test_a100_has_no_2_4_1_style_invalid_partition():
    # paper §2.3: 2-4-1 with the 4 in the middle is NOT a valid partition
    for p in A100.valid_partitions:
        sizes_at = sorted((n.start, n.size) for n in p)
        assert (2, 4) not in sizes_at  # no 4-slice instance starting at S2


def test_a100_special_three_instance_blocks_s3():
    threes = [n for n in A100.nodes if n.size == 3]
    assert len(threes) == 2
    left = next(n for n in threes if n.start == 0)
    assert left.footprint == 4  # S3 reserved-idle
    right = next(n for n in threes if n.start == 4)
    assert right.footprint == 3


def test_disjoint_node_sets_are_feasible():
    by_key = {(n.start, n.size): n for n in A100.nodes
              if n.footprint == n.size}
    four = next(n for n in A100.nodes if n.size == 4)
    combo = [four, by_key[(4, 2)], by_key[(6, 1)]]  # 4 + (4,2) + (6,1)
    assert A100.is_feasible_instance_set(combo)
    seven = next(n for n in A100.nodes if n.size == 7)
    bad = [seven, by_key[(0, 1)]]  # overlapping footprints
    assert not A100.is_feasible_instance_set(bad)


def test_multi_gpu_forest():
    spec = multi_gpu(A30, 3)
    assert spec.n_slices == 12
    assert len(spec.roots) == 3
    assert len(spec.valid_partitions) == 5 ** 3


def test_superpod_is_two_pods():
    assert TPU_SUPERPOD_512.n_slices == 16
    assert len(TPU_SUPERPOD_512.roots) == 2


@pytest.mark.parametrize("dead,expect_slices", [
    ([(0, 0)], 7), ([(0, 0), (0, 7)], 6), ([(0, 3)], 7),
])
def test_degrade_removes_only_affected_subtrees(dead, expect_slices):
    d = TPU_POD_256.degrade(dead)
    assert d.n_slices == expect_slices
    for r in d.roots:
        for s in r.blocked:
            assert (r.tree, s) not in set(dead)
    # sizes remain schedulable subset
    assert set(d.sizes) <= set(TPU_POD_256.sizes)


def test_degrade_keeps_t_tables():
    d = A100.degrade([(0, 6)])
    for s in d.sizes:
        assert s in d.t_create and s in d.t_destroy


def test_degrade_drops_stale_reconfig_table_entries():
    """The tables shrink with the sizes: no create/destroy cost may
    survive for an instance size the degraded tree can no longer form."""
    d = A30.degrade([(0, 0)])  # kills the 4 and the left 2
    assert set(d.sizes) == {1, 2}
    assert set(d.t_create) == set(d.sizes)
    assert set(d.t_destroy) == set(d.sizes)
    assert d.device_kind == "A30"  # kind survives renaming


def test_degrade_to_empty_forest():
    dead = [(0, s) for s in range(4)]
    d = A30.degrade(dead)
    assert d.roots == ()
    assert d.sizes == ()
    assert d.t_create == {} and d.t_destroy == {}
    assert d.n_slices == 0


def test_degrade_a100_footprint4_three_instance():
    """Killing S3 removes the special 3-with-S3-idle instance (footprint
    4) along with the 4 and the root, leaving 2(S0,S1), 1(S2) and the
    right-hand 3 — and the tables shrink to the surviving sizes."""
    d = A100.degrade([(0, 3)])
    assert not any(n.footprint != n.size for n in d.nodes)  # the 3' is gone
    roots = sorted((r.start, r.size) for r in d.roots)
    assert roots == [(0, 2), (2, 1), (4, 3)]
    assert set(d.sizes) == {1, 2, 3}
    assert set(d.t_create) == {1, 2, 3}


def test_degrade_inside_cluster():
    from repro.core.cluster import cluster

    cs = cluster(A30, A100)
    a100_tree = cs.devices[1].roots[0].tree
    d1 = cs.degrade([(a100_tree, 3)])
    assert len(d1.devices) == 2
    assert d1.devices[0].sizes == A30.sizes          # untouched device
    assert set(d1.devices[1].sizes) == {1, 2, 3}     # degraded A100
    assert d1.devices[1].device_kind == "A100"
    # tree ids keep their global identity through degradation
    assert {r.tree for r in d1.devices[1].roots} == {a100_tree}
    # killing every A30 slice drops the device from the pool
    a30_tree = cs.devices[0].roots[0].tree
    d2 = cs.degrade([(a30_tree, s) for s in range(4)])
    assert len(d2.devices) == 1
    assert d2.devices[0].device_kind == "A100"
