"""Device-spec structure: valid partitions, trees, degradation."""

import pytest

from repro.core.device_spec import (
    A30, A100, H100, TPU_POD_256, TPU_SUPERPOD_512, multi_gpu,
)


def test_partition_counts_match_paper_fig1():
    assert len(A30.valid_partitions) == 5
    assert len(A100.valid_partitions) == 19
    assert len(H100.valid_partitions) == 19


def test_partitions_tile_all_slices():
    for spec in (A30, A100, TPU_POD_256):
        for p in spec.valid_partitions:
            blocked = sorted(
                (node.tree, s) for node in p for s in node.blocked
            )
            want = sorted(
                (r.tree, s) for r in spec.roots for s in r.blocked
            )
            assert blocked == want, (spec.name, p)


def test_a100_has_no_2_4_1_style_invalid_partition():
    # paper §2.3: 2-4-1 with the 4 in the middle is NOT a valid partition
    for p in A100.valid_partitions:
        sizes_at = sorted((n.start, n.size) for n in p)
        assert (2, 4) not in sizes_at  # no 4-slice instance starting at S2


def test_a100_special_three_instance_blocks_s3():
    threes = [n for n in A100.nodes if n.size == 3]
    assert len(threes) == 2
    left = next(n for n in threes if n.start == 0)
    assert left.footprint == 4  # S3 reserved-idle
    right = next(n for n in threes if n.start == 4)
    assert right.footprint == 3


def test_disjoint_node_sets_are_feasible():
    by_key = {(n.start, n.size): n for n in A100.nodes
              if n.footprint == n.size}
    four = next(n for n in A100.nodes if n.size == 4)
    combo = [four, by_key[(4, 2)], by_key[(6, 1)]]  # 4 + (4,2) + (6,1)
    assert A100.is_feasible_instance_set(combo)
    seven = next(n for n in A100.nodes if n.size == 7)
    bad = [seven, by_key[(0, 1)]]  # overlapping footprints
    assert not A100.is_feasible_instance_set(bad)


def test_multi_gpu_forest():
    spec = multi_gpu(A30, 3)
    assert spec.n_slices == 12
    assert len(spec.roots) == 3
    assert len(spec.valid_partitions) == 5 ** 3


def test_superpod_is_two_pods():
    assert TPU_SUPERPOD_512.n_slices == 16
    assert len(TPU_SUPERPOD_512.roots) == 2


@pytest.mark.parametrize("dead,expect_slices", [
    ([(0, 0)], 7), ([(0, 0), (0, 7)], 6), ([(0, 3)], 7),
])
def test_degrade_removes_only_affected_subtrees(dead, expect_slices):
    d = TPU_POD_256.degrade(dead)
    assert d.n_slices == expect_slices
    for r in d.roots:
        for s in r.blocked:
            assert (r.tree, s) not in set(dead)
    # sizes remain schedulable subset
    assert set(d.sizes) <= set(TPU_POD_256.sizes)


def test_degrade_keeps_t_tables():
    d = A100.degrade([(0, 6)])
    for s in d.sizes:
        assert s in d.t_create and s in d.t_destroy
