"""Replay-equivalence contract of the incremental timing engine.

``TimingEngine`` promises: after ANY sequence of moves/swaps/appends and
undos, every accessor returns exactly what a fresh ``replay()`` of the same
assignment would — for both ``include_reconfig`` settings, both directions,
and with/without seam carry-over state.  ``ReplayEngine`` is the reference
implementation of the same API; these tests drive both through identical
edit sequences and require *exact* (``==``, not EPS) agreement, plus
end-to-end agreement of the engine-backed refinement paths with the
replay-backed ones.
"""

import random

import pytest

from repro.core.device_spec import A30, A100, TPU_POD_256
from repro.core.far import schedule_batch
from repro.core.policy import SchedulerConfig
from repro.core.multibatch import MultiBatchScheduler, Tail, seam_refine
from repro.core.problem import validate_schedule
from repro.core.refine import refine_assignment
from repro.core.repartition import (
    LPTGroups,
    list_schedule_allocation,
    replay,
)
from repro.core.allocations import allocation_family
from repro.core.synth import generate_tasks, workload
from repro.core.timing import ReplayEngine, TimingEngine

NO_REFINE = SchedulerConfig(refine=False)

SPECS = (A30, A100, TPU_POD_256)


def _assert_engines_agree(eng: TimingEngine, ref: ReplayEngine):
    for flag in (True, False):
        assert eng.makespan(flag) == ref.makespan(flag)
        assert eng.slice_end_times(flag) == ref.slice_end_times(flag)
        assert eng.node_end_times(flag) == ref.node_end_times(flag)
        assert eng.begin_mass(flag) == ref.begin_mass(flag)
    sched_e, sched_r = eng.schedule(), ref.schedule()
    assert sched_e.items == sched_r.items
    assert sched_e.reconfigs == sched_r.reconfigs


def _random_edit(rng, eng, ref, spec):
    """Apply one random valid edit to both engines; returns False if none."""
    occupied = [k for k, v in eng.chains.items() if v]
    if not occupied:
        return False
    kind = rng.choice(["move", "move", "swap"])
    if kind == "move":
        src = rng.choice(occupied)
        tid = rng.choice(eng.chains[src])
        dst = rng.choice([n.key for n in spec.nodes if n.key != src])
        eng.apply_move(tid, dst=dst, src=src)
        ref.apply_move(tid, dst=dst, src=src)
    else:
        if len(occupied) < 2:
            return False
        ka, kb = rng.sample(occupied, 2)
        ta = rng.choice(eng.chains[ka])
        tb = rng.choice(eng.chains[kb])
        eng.apply_swap(ta, tb)
        ref.apply_swap(ta, tb)
    return True


def _seam_tail(spec, seed):
    mb = MultiBatchScheduler(spec, mode="trivial")
    mb.add_batch(
        generate_tasks(6, spec, workload("mixed", "wide", spec), seed=seed)
    )
    return mb.tail


@pytest.mark.parametrize("spec", SPECS)
@pytest.mark.parametrize("direction", ["forward", "reverse"])
@pytest.mark.parametrize("with_tail", [False, True])
def test_engine_matches_replay_under_random_edits(spec, direction, with_tail):
    rng = random.Random(1234 + spec.n_slices)
    tasks = generate_tasks(
        12, spec, workload("mixed", "wide", spec), seed=3, id_offset=100
    )
    fam = allocation_family(tasks, spec)
    assignment = list_schedule_allocation(tasks, fam[len(fam) // 2], spec)
    ctx = {}
    if with_tail:
        tail = _seam_tail(spec, seed=7)
        ctx = dict(release=tail.release, alive=tail.alive)
    eng = TimingEngine(assignment, direction=direction, **ctx)
    ref = ReplayEngine(assignment, direction=direction, **ctx)
    snapshot = {k: list(v) for k, v in eng.chains.items()}
    _assert_engines_agree(eng, ref)
    for _ in range(25):
        if not _random_edit(rng, eng, ref, spec):
            break
        _assert_engines_agree(eng, ref)
    # speculative use: undo everything, bit-identical initial state + timing
    eng.undo_all()
    ref.undo_all()
    assert {k: v for k, v in eng.chains.items() if v} == \
        {k: v for k, v in snapshot.items() if v}
    _assert_engines_agree(eng, ref)


def test_engine_undo_interleaved_with_evaluation():
    spec = A100
    tasks = generate_tasks(10, spec, workload("good", "wide", spec), seed=5)
    assignment = schedule_batch(tasks, spec, NO_REFINE).assignment
    eng = TimingEngine(assignment)
    rng = random.Random(99)
    before = {
        flag: (eng.makespan(flag), eng.slice_end_times(flag))
        for flag in (True, False)
    }
    for _ in range(10):
        ref = ReplayEngine(eng.export_assignment())
        n_edits = rng.randint(1, 3)
        done = 0
        for _ in range(n_edits):
            if _random_edit(rng, eng, ref, spec):
                done += 1
        _assert_engines_agree(eng, ref)
        for _ in range(done):
            eng.undo()
        for flag in (True, False):
            assert (eng.makespan(flag), eng.slice_end_times(flag)) \
                == before[flag]


def test_task_begin_end_matches_schedule():
    spec = A100
    tasks = generate_tasks(9, spec, workload("poor", "narrow", spec), seed=2)
    assignment = schedule_batch(tasks, spec, NO_REFINE).assignment
    for direction in ("forward", "reverse"):
        eng = TimingEngine(assignment, direction=direction)
        sched = replay(assignment, direction=direction)
        for it in sched.items:
            assert eng.task_begin_end(it.task.id) == (it.begin, it.end)


def test_lpt_groups_warm_start_matches_cold_sort():
    spec = A100
    tasks = generate_tasks(15, spec, workload("mixed", "wide", spec), seed=11)
    fam = allocation_family(tasks, spec)
    groups = LPTGroups(tasks, fam[0], spec)
    for idx, alloc in enumerate(fam):
        if idx:
            prev = fam[idx - 1]
            j = next(i for i in range(len(alloc)) if alloc[i] != prev[i])
            groups.move(tasks[j], prev[j], alloc[j])
        warm = groups.schedule()
        cold = list_schedule_allocation(tasks, alloc, spec)
        assert warm.node_tasks == cold.node_tasks


@pytest.mark.parametrize("spec", SPECS)
def test_refine_engine_path_equals_replay_path(spec):
    for scaling, times in (("mixed", "wide"), ("poor", "narrow"),
                           ("good", "wide")):
        for n in (10, 22):
            tasks = generate_tasks(
                n, spec, workload(scaling, times, spec), seed=n
            )
            base = schedule_batch(tasks, spec, NO_REFINE).assignment
            a_asgn, a_sched, a_stats = refine_assignment(base, use_engine=True)
            b_asgn, b_sched, b_stats = refine_assignment(base, use_engine=False)
            assert a_sched.makespan == b_sched.makespan
            assert a_asgn.node_tasks == b_asgn.node_tasks
            assert (a_stats.moves, a_stats.swaps, a_stats.iterations) == \
                (b_stats.moves, b_stats.swaps, b_stats.iterations)


def test_seam_refine_engine_path_equals_replay_path():
    spec = A100
    for seed in range(3):
        tail = _seam_tail(spec, seed)
        batch = generate_tasks(
            10, spec, workload("mixed", "wide", spec),
            seed=seed + 50, id_offset=500,
        )
        asgn = schedule_batch(batch, spec).assignment
        for direction in ("forward", "reverse"):
            a = seam_refine(asgn, tail, direction, use_engine=True)
            b = seam_refine(asgn, tail, direction, use_engine=False)
            assert a[1].makespan == b[1].makespan
            assert a[0].node_tasks == b[0].node_tasks
            assert a[2:] == b[2:]  # move/swap counts


def test_schedule_batch_paths_identical_on_t4_t9_workloads():
    """Acceptance: phase-3 + seam move/swap makespans identical between the
    incremental-engine pipeline and the replay-per-query pipeline on the
    benchmark workload family (t4-t9 use these generators)."""
    spec = A100
    for scaling, times in (("poor", "wide"), ("mixed", "wide"),
                           ("good", "wide"), ("mixed", "narrow")):
        cfg = workload(scaling, times, spec)
        for n in (10, 30):
            tasks = generate_tasks(n, spec, cfg, seed=n)
            a = schedule_batch(tasks, spec, SchedulerConfig(use_engine=True))
            b = schedule_batch(tasks, spec, SchedulerConfig(use_engine=False))
            assert a.makespan == b.makespan
            assert a.assignment.node_tasks == b.assignment.node_tasks
            validate_schedule(a.schedule, tasks)
        # multi-batch chain with seam move/swap (t9)
        me = MultiBatchScheduler(spec, mode="move_swap", use_engine=True)
        mr = MultiBatchScheduler(spec, mode="move_swap", use_engine=False)
        for s in range(3):
            b = generate_tasks(8, spec, cfg, seed=s, id_offset=10_000 * s)
            me.add_batch(b)
            mr.add_batch(b)
        assert me.makespan == mr.makespan
        assert me.tail.release == mr.tail.release


def test_empty_and_single_task_engine():
    spec = A100
    from repro.core.repartition import Assignment

    empty = Assignment(spec, {}, {})
    eng = TimingEngine(empty)
    assert eng.makespan() == 0.0
    assert eng.schedule().items == []
    t = generate_tasks(1, spec, workload("mixed", "wide", spec), seed=0)
    asgn = schedule_batch(t, spec).assignment
    _assert_engines_agree(TimingEngine(asgn), ReplayEngine(asgn))


# --- property-based fuzz (runs only when hypothesis is installed) ----------
try:
    from hypothesis import given, settings
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from repro.core.problem import Task

    @st.composite
    def assignment_and_edits(draw):
        spec = {"A30": A30, "A100": A100, "TPU": TPU_POD_256}[
            draw(st.sampled_from(["A30", "A100", "TPU"]))
        ]
        n = draw(st.integers(1, 8))
        tasks = []
        for i in range(n):
            t1 = draw(st.floats(0.5, 100.0, allow_nan=False))
            times, cur = {}, t1
            for s in spec.sizes:
                if s != min(spec.sizes):
                    cur *= draw(st.floats(0.3, 1.0))
                times[s] = cur
            tasks.append(Task(id=i, times=times))
        fam = allocation_family(tasks, spec)
        alloc = fam[draw(st.integers(0, len(fam) - 1))]
        seed = draw(st.integers(0, 2**16))
        direction = draw(st.sampled_from(["forward", "reverse"]))
        return spec, tasks, alloc, seed, direction

    @settings(max_examples=30, deadline=None)
    @given(assignment_and_edits())
    def test_engine_equivalence_hypothesis(case):
        spec, tasks, alloc, seed, direction = case
        assignment = list_schedule_allocation(tasks, alloc, spec)
        eng = TimingEngine(assignment, direction=direction)
        ref = ReplayEngine(assignment, direction=direction)
        rng = random.Random(seed)
        _assert_engines_agree(eng, ref)
        for _ in range(8):
            if not _random_edit(rng, eng, ref, spec):
                break
            _assert_engines_agree(eng, ref)
        eng.undo_all()
        ref.undo_all()
        _assert_engines_agree(eng, ref)
