"""Replay-equivalence contract of the incremental timing engine.

``TimingEngine`` promises: after ANY sequence of moves/swaps/appends and
undos, every accessor returns exactly what a fresh ``replay()`` of the same
assignment would — for both ``include_reconfig`` settings, both directions,
and with/without seam carry-over state.  ``ReplayEngine`` is the reference
implementation of the same API; these tests drive both through identical
edit sequences and require *exact* (``==``, not EPS) agreement, plus
end-to-end agreement of the engine-backed refinement paths with the
replay-backed ones.
"""

import dataclasses
import random

import pytest

from repro.core.device_spec import A30, A100, TPU_POD_256, InstanceNode
from repro.core.far import schedule_batch
from repro.core.policy import SchedulerConfig
from repro.core.multibatch import MultiBatchScheduler, Tail, seam_refine
from repro.core.problem import validate_schedule
from repro.core.refine import refine_assignment
from repro.core.repartition import (
    LPTGroups,
    list_schedule_allocation,
    replay,
)
from repro.core.allocations import allocation_family
from repro.core.synth import generate_tasks, workload
from repro.core.timing import ReplayEngine, TimingEngine

NO_REFINE = SchedulerConfig(refine=False)

SPECS = (A30, A100, TPU_POD_256)


def _assert_engines_agree(eng: TimingEngine, ref: ReplayEngine):
    for flag in (True, False):
        assert eng.makespan(flag) == ref.makespan(flag)
        assert eng.slice_end_times(flag) == ref.slice_end_times(flag)
        assert eng.node_end_times(flag) == ref.node_end_times(flag)
        assert eng.begin_mass(flag) == ref.begin_mass(flag)
    sched_e, sched_r = eng.schedule(), ref.schedule()
    assert sched_e.items == sched_r.items
    assert sched_e.reconfigs == sched_r.reconfigs


def _random_edit(rng, eng, ref, spec):
    """Apply one random valid edit to both engines; returns False if none."""
    occupied = [k for k, v in eng.chains.items() if v]
    if not occupied:
        return False
    kind = rng.choice(["move", "move", "swap"])
    if kind == "move":
        src = rng.choice(occupied)
        tid = rng.choice(eng.chains[src])
        dst = rng.choice([n.key for n in spec.nodes if n.key != src])
        eng.apply_move(tid, dst=dst, src=src)
        ref.apply_move(tid, dst=dst, src=src)
    else:
        if len(occupied) < 2:
            return False
        ka, kb = rng.sample(occupied, 2)
        ta = rng.choice(eng.chains[ka])
        tb = rng.choice(eng.chains[kb])
        eng.apply_swap(ta, tb)
        ref.apply_swap(ta, tb)
    return True


def _seam_tail(spec, seed):
    mb = MultiBatchScheduler(spec, mode="trivial")
    mb.add_batch(
        generate_tasks(6, spec, workload("mixed", "wide", spec), seed=seed)
    )
    return mb.tail


@pytest.mark.parametrize("spec", SPECS)
@pytest.mark.parametrize("direction", ["forward", "reverse"])
@pytest.mark.parametrize("with_tail", [False, True])
def test_engine_matches_replay_under_random_edits(spec, direction, with_tail):
    rng = random.Random(1234 + spec.n_slices)
    tasks = generate_tasks(
        12, spec, workload("mixed", "wide", spec), seed=3, id_offset=100
    )
    fam = allocation_family(tasks, spec)
    assignment = list_schedule_allocation(tasks, fam[len(fam) // 2], spec)
    ctx = {}
    if with_tail:
        tail = _seam_tail(spec, seed=7)
        ctx = dict(release=tail.release, alive=tail.alive)
    eng = TimingEngine(assignment, direction=direction, **ctx)
    ref = ReplayEngine(assignment, direction=direction, **ctx)
    snapshot = {k: list(v) for k, v in eng.chains.items()}
    _assert_engines_agree(eng, ref)
    for _ in range(25):
        if not _random_edit(rng, eng, ref, spec):
            break
        _assert_engines_agree(eng, ref)
    # speculative use: undo everything, bit-identical initial state + timing
    eng.undo_all()
    ref.undo_all()
    assert {k: v for k, v in eng.chains.items() if v} == \
        {k: v for k, v in snapshot.items() if v}
    _assert_engines_agree(eng, ref)


def test_engine_undo_interleaved_with_evaluation():
    spec = A100
    tasks = generate_tasks(10, spec, workload("good", "wide", spec), seed=5)
    assignment = schedule_batch(tasks, spec, NO_REFINE).assignment
    eng = TimingEngine(assignment)
    rng = random.Random(99)
    before = {
        flag: (eng.makespan(flag), eng.slice_end_times(flag))
        for flag in (True, False)
    }
    for _ in range(10):
        ref = ReplayEngine(eng.export_assignment())
        n_edits = rng.randint(1, 3)
        done = 0
        for _ in range(n_edits):
            if _random_edit(rng, eng, ref, spec):
                done += 1
        _assert_engines_agree(eng, ref)
        for _ in range(done):
            eng.undo()
        for flag in (True, False):
            assert (eng.makespan(flag), eng.slice_end_times(flag)) \
                == before[flag]


def test_task_begin_end_matches_schedule():
    spec = A100
    tasks = generate_tasks(9, spec, workload("poor", "narrow", spec), seed=2)
    assignment = schedule_batch(tasks, spec, NO_REFINE).assignment
    for direction in ("forward", "reverse"):
        eng = TimingEngine(assignment, direction=direction)
        sched = replay(assignment, direction=direction)
        for it in sched.items:
            assert eng.task_begin_end(it.task.id) == (it.begin, it.end)


def test_lpt_groups_warm_start_matches_cold_sort():
    spec = A100
    tasks = generate_tasks(15, spec, workload("mixed", "wide", spec), seed=11)
    fam = allocation_family(tasks, spec)
    groups = LPTGroups(tasks, fam[0], spec)
    for idx, alloc in enumerate(fam):
        if idx:
            prev = fam[idx - 1]
            j = next(i for i in range(len(alloc)) if alloc[i] != prev[i])
            groups.move(tasks[j], prev[j], alloc[j])
        warm = groups.schedule()
        cold = list_schedule_allocation(tasks, alloc, spec)
        assert warm.node_tasks == cold.node_tasks


@pytest.mark.parametrize("spec", SPECS)
def test_refine_engine_path_equals_replay_path(spec):
    for scaling, times in (("mixed", "wide"), ("poor", "narrow"),
                           ("good", "wide")):
        for n in (10, 22):
            tasks = generate_tasks(
                n, spec, workload(scaling, times, spec), seed=n
            )
            base = schedule_batch(tasks, spec, NO_REFINE).assignment
            a_asgn, a_sched, a_stats = refine_assignment(base, use_engine=True)
            b_asgn, b_sched, b_stats = refine_assignment(base, use_engine=False)
            assert a_sched.makespan == b_sched.makespan
            assert a_asgn.node_tasks == b_asgn.node_tasks
            assert (a_stats.moves, a_stats.swaps, a_stats.iterations) == \
                (b_stats.moves, b_stats.swaps, b_stats.iterations)


def test_seam_refine_engine_path_equals_replay_path():
    spec = A100
    for seed in range(3):
        tail = _seam_tail(spec, seed)
        batch = generate_tasks(
            10, spec, workload("mixed", "wide", spec),
            seed=seed + 50, id_offset=500,
        )
        asgn = schedule_batch(batch, spec).assignment
        for direction in ("forward", "reverse"):
            a = seam_refine(asgn, tail, direction, use_engine=True)
            b = seam_refine(asgn, tail, direction, use_engine=False)
            assert a[1].makespan == b[1].makespan
            assert a[0].node_tasks == b[0].node_tasks
            assert a[2:] == b[2:]  # move/swap counts


def test_schedule_batch_paths_identical_on_t4_t9_workloads():
    """Acceptance: phase-3 + seam move/swap makespans identical between the
    incremental-engine pipeline and the replay-per-query pipeline on the
    benchmark workload family (t4-t9 use these generators)."""
    spec = A100
    for scaling, times in (("poor", "wide"), ("mixed", "wide"),
                           ("good", "wide"), ("mixed", "narrow")):
        cfg = workload(scaling, times, spec)
        for n in (10, 30):
            tasks = generate_tasks(n, spec, cfg, seed=n)
            a = schedule_batch(tasks, spec, SchedulerConfig(use_engine=True))
            b = schedule_batch(tasks, spec, SchedulerConfig(use_engine=False))
            assert a.makespan == b.makespan
            assert a.assignment.node_tasks == b.assignment.node_tasks
            validate_schedule(a.schedule, tasks)
        # multi-batch chain with seam move/swap (t9)
        me = MultiBatchScheduler(spec, mode="move_swap", use_engine=True)
        mr = MultiBatchScheduler(spec, mode="move_swap", use_engine=False)
        for s in range(3):
            b = generate_tasks(8, spec, cfg, seed=s, id_offset=10_000 * s)
            me.add_batch(b)
            mr.add_batch(b)
        assert me.makespan == mr.makespan
        assert me.tail.release == mr.tail.release


def test_empty_and_single_task_engine():
    spec = A100
    from repro.core.repartition import Assignment

    empty = Assignment(spec, {}, {})
    eng = TimingEngine(empty)
    assert eng.makespan() == 0.0
    assert eng.schedule().items == []
    t = generate_tasks(1, spec, workload("mixed", "wide", spec), seed=0)
    asgn = schedule_batch(t, spec).assignment
    _assert_engines_agree(TimingEngine(asgn), ReplayEngine(asgn))


# --- suffix retraction (serving re-planning pulls appends back) ------------

def _snapshot(eng):
    # empty chains are inactive (and undo of an append leaves one behind,
    # matching the engine's existing behavior) — compare modulo them
    return (
        {k: list(v) for k, v in eng.chains.items() if v},
        {k: list(v) for k, v in eng.durs.items() if v},
    )


@pytest.mark.parametrize("spec", SPECS)
@pytest.mark.parametrize("with_tail", [False, True])
def test_retract_inverts_append_bit_for_bit(spec, with_tail):
    tasks = generate_tasks(
        6, spec, workload("mixed", "wide", spec), seed=11, id_offset=300
    )
    ctx = {}
    if with_tail:
        tail = _seam_tail(spec, seed=4)
        ctx = dict(release=tail.release, alive=tail.alive)
    base = schedule_batch(tasks[:3], spec, NO_REFINE).assignment
    eng = TimingEngine(base, **ctx)
    ref = ReplayEngine(base, **ctx)
    before = _snapshot(eng)
    m0 = eng.makespan()
    node = spec.nodes[0]
    for t in tasks[3:]:
        eng.tasks[t.id] = t   # the tasks dict is shared with `ref`
        eng.apply_append(t.id, node.key)
        ref.apply_append(t.id, node.key)
    _assert_engines_agree(eng, ref)
    for t in reversed(tasks[3:]):
        eng.apply_retract(t.id)
        ref.apply_retract(t.id, node.key)
    _assert_engines_agree(eng, ref)
    assert _snapshot(eng) == before
    assert eng.makespan() == m0
    # undo() of a retraction restores the retracted task exactly
    eng.apply_append(tasks[3].id, node.key)
    mid = _snapshot(eng)
    eng.apply_retract(tasks[3].id)
    eng.undo()
    assert _snapshot(eng) == mid
    assert eng.task_node[tasks[3].id] == node.key


def test_retract_suffix_and_error_cases():
    spec = A100
    tasks = generate_tasks(
        4, spec, workload("mixed", "wide", spec), seed=2, id_offset=500
    )
    from repro.core.repartition import Assignment

    eng = TimingEngine(Assignment(spec, {t.id: t for t in tasks}, {}))
    key = spec.nodes[0].key
    for t in tasks:
        eng.apply_append(t.id, key)
    # only the chain tail may be retracted (no-preemption: retracting an
    # interior task would shift the started work behind it)
    with pytest.raises(ValueError, match="suffix"):
        eng.apply_retract(tasks[0].id)
    # suffix retraction pops newest-first and reports the order
    assert eng.retract_suffix(key, 2) == [tasks[3].id, tasks[2].id]
    assert eng.chains[key] == [tasks[0].id, tasks[1].id]
    with pytest.raises(ValueError, match="retract 5"):
        eng.retract_suffix(key, 5)
    eng.retract_suffix(key, 2)
    assert eng.chains[key] == []
    # empty chain: nothing to retract
    with pytest.raises(ValueError, match="suffix"):
        eng.apply_retract(tasks[0].id, key)
    # the whole episode unwinds to the empty assignment
    eng.undo_all()
    assert eng.chains[key] == []
    assert eng.makespan() == 0.0


def test_online_withdraw_not_started_uses_retraction():
    """OnlineScheduler.withdraw_not_started pulls exactly the placements
    beginning after t, and the surviving schedule re-times consistently
    (survivors may only move earlier, never before the cut)."""
    from repro.core.online import OnlineScheduler

    spec = A100
    tasks = generate_tasks(
        10, spec, workload("mixed", "wide", spec), seed=6, id_offset=700
    )
    sched = OnlineScheduler(spec)
    for t in tasks:
        sched.submit(t)
    cut = sched.makespan / 2
    # read current timings (submit-time placement stamps go stale: later
    # appends can reshuffle the reconfiguration sequence)
    old_begin = {it.task.id: it.begin for it in sched.schedule().items}
    started = {tid for tid, b in old_begin.items() if b <= cut + 1e-9}
    withdrawn = sched.withdraw_not_started(cut)
    kept = {p.task_id for p in sched.placements}
    assert kept | {t.id for t in withdrawn} == {t.id for t in tasks}
    # "started" is judged against the pre-withdrawal timings: exactly the
    # started set survives, everything else is pulled back
    assert kept == started
    validate_schedule(sched.schedule(), check_reconfig=True)
    for p in sched.placements:      # survivors only ever move earlier
        assert p.begin <= old_begin[p.task_id] + 1e-9


# --- batched phase-2 scorer edge cases -------------------------------------

#: a degenerate one-instance device: the repartitioning tree is a single
#: leaf, so the event walk reduces to create + fold — the smallest spec
#: the batched scorer must still get bit-exact
SINGLE = dataclasses.replace(
    A30,
    name="single",
    roots=(InstanceNode(0, 0, 1, 1),),
    sizes=(1,),
    t_create={1: 0.11},
    t_destroy={1: 0.10},
)


def _batch_arrays(spec, cands):
    """(C, N, L) duration tensor + (C, N) lengths from per-node dicts."""
    import numpy as np

    index = {node.key: i for i, node in enumerate(spec.nodes)}
    N = len(spec.nodes)
    L = max((len(v) for nd in cands for v in nd.values()), default=1)
    cd = np.zeros((len(cands), N, max(L, 1)))
    cl = np.zeros((len(cands), N), dtype=np.int64)
    for c, nd in enumerate(cands):
        for key, durs in nd.items():
            cd[c, index[key], :len(durs)] = durs
            cl[c, index[key]] = len(durs)
    return cd, cl


def test_chains_makespan_batch_single_node_device():
    from repro.core.timing import chains_makespan, chains_makespan_batch

    root = SINGLE.roots[0]
    cands = [
        {},                                   # empty candidate
        {root.key: [2.0]},                    # one task
        {root.key: [3.0, 2.0, 1.0]},          # a chain
        {root.key: [1.0] * 7},                # ties
    ]
    cd, cl = _batch_arrays(SINGLE, cands)
    batch = chains_makespan_batch(SINGLE, cd, cl)
    for c, nd in enumerate(cands):
        ids = {k: list(range(len(v))) for k, v in nd.items()}
        assert batch[c] == chains_makespan(SINGLE, ids, nd)
    assert batch[0] == 0.0
    assert batch[1] == SINGLE.t_create[1] + 2.0


def test_chains_makespan_batch_all_ties_integer_durations():
    """The EPS-ordered-winner regression class from PR 3: integer
    durations tied across every chain still score bit-identically to the
    sequential walk (same heap tie-breaking, same fold order)."""
    from repro.core.timing import chains_makespan, chains_makespan_batch

    spec = A100
    ones = [n.key for n in spec.nodes if n.size == 1]
    twos = [n.key for n in spec.nodes if n.size == 2]
    cands = [
        {k: [1.0, 1.0, 1.0] for k in ones},
        {k: [2.0, 2.0] for k in ones[:3]} | {k: [2.0] for k in twos},
        {k: [1.0] for k in ones} | {twos[0]: [1.0, 1.0]},
        {ones[0]: []},                        # all-empty row
    ]
    cd, cl = _batch_arrays(spec, cands)
    batch = chains_makespan_batch(spec, cd, cl)
    for c, nd in enumerate(cands):
        ids = {k: list(range(len(v))) for k, v in nd.items()}
        assert batch[c] == chains_makespan(spec, ids, nd)
    assert batch[3] == 0.0


def test_chains_makespan_batch_mixed_empty_and_padded_rows():
    """Zero-length rows beside fully-padded ones: the walk must ignore
    padding past chain_len and inactive nodes entirely."""
    import numpy as np

    from repro.core.timing import chains_makespan, chains_makespan_batch

    spec = A30
    key0 = spec.nodes[1].key  # a non-root node
    nd = {key0: [4.0, 3.0]}
    cd, cl = _batch_arrays(spec, [nd, {}])
    # poison every slot past chain_len: padding must never be read
    L = cd.shape[2]
    cd[np.arange(L)[None, None, :] >= cl[:, :, None]] = 77.0
    batch = chains_makespan_batch(spec, cd, cl)
    assert batch[0] == chains_makespan(
        spec, {key0: [0, 1]}, nd
    )
    assert batch[1] == 0.0


# --- property-based fuzz (runs only when hypothesis is installed) ----------
try:
    from hypothesis import given, settings
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from repro.core.problem import Task

    @st.composite
    def assignment_and_edits(draw):
        spec = {"A30": A30, "A100": A100, "TPU": TPU_POD_256}[
            draw(st.sampled_from(["A30", "A100", "TPU"]))
        ]
        n = draw(st.integers(1, 8))
        tasks = []
        for i in range(n):
            t1 = draw(st.floats(0.5, 100.0, allow_nan=False))
            times, cur = {}, t1
            for s in spec.sizes:
                if s != min(spec.sizes):
                    cur *= draw(st.floats(0.3, 1.0))
                times[s] = cur
            tasks.append(Task(id=i, times=times))
        fam = allocation_family(tasks, spec)
        alloc = fam[draw(st.integers(0, len(fam) - 1))]
        seed = draw(st.integers(0, 2**16))
        direction = draw(st.sampled_from(["forward", "reverse"]))
        return spec, tasks, alloc, seed, direction

    @settings(max_examples=30, deadline=None)
    @given(assignment_and_edits())
    def test_engine_equivalence_hypothesis(case):
        spec, tasks, alloc, seed, direction = case
        assignment = list_schedule_allocation(tasks, alloc, spec)
        eng = TimingEngine(assignment, direction=direction)
        ref = ReplayEngine(assignment, direction=direction)
        rng = random.Random(seed)
        _assert_engines_agree(eng, ref)
        for _ in range(8):
            if not _random_edit(rng, eng, ref, spec):
                break
            _assert_engines_agree(eng, ref)
        eng.undo_all()
        ref.undo_all()
        _assert_engines_agree(eng, ref)


# --- runtime-truth stretches (closed-loop corrections) ---------------------

def test_apply_stretch_retimes_successors_and_undoes_exactly():
    spec = A100
    tasks = generate_tasks(
        4, spec, workload("mixed", "wide", spec), seed=9, id_offset=700
    )
    from repro.core.repartition import Assignment

    eng = TimingEngine(Assignment(spec, {t.id: t for t in tasks}, {}))
    key = spec.nodes[0].key
    for t in tasks:
        eng.apply_append(t.id, key)
    before = _snapshot(eng)
    m0 = eng.makespan()
    first = tasks[0]
    planned = first.times[spec.nodes[0].size]
    eng.apply_stretch(first.id, planned * 3.0)
    # the whole chain behind the stretched task shifts by the delta
    assert eng.makespan() == pytest.approx(m0 + 2.0 * planned)
    sched = eng.schedule()
    stretched_item = next(it for it in sched.items if it.task.id == first.id)
    assert stretched_item.end_override is not None
    assert stretched_item.corrected
    assert stretched_item.duration == pytest.approx(3.0 * planned)
    # shrink on top of the stretch: latest truth wins
    eng.apply_stretch(first.id, planned * 0.5)
    assert eng.makespan() == pytest.approx(m0 - 0.5 * planned)
    # undo unwinds both corrections exactly
    eng.undo()
    assert eng.makespan() == pytest.approx(m0 + 2.0 * planned)
    eng.undo()
    assert _snapshot(eng) == before
    assert eng.makespan() == m0
    assert first.id not in eng.stretched
    sched2 = eng.schedule()
    assert all(it.end_override is None for it in sched2.items)


def test_apply_stretch_sticks_through_retract_undo():
    """A stretched task that is retracted and then restored by undo()
    keeps its corrected duration (the correction is state, not an edit
    on the restored placement)."""
    spec = A30
    tasks = generate_tasks(
        3, spec, workload("mixed", "wide", spec), seed=5, id_offset=720
    )
    from repro.core.repartition import Assignment

    eng = TimingEngine(Assignment(spec, {t.id: t for t in tasks}, {}))
    key = spec.nodes[0].key
    for t in tasks:
        eng.apply_append(t.id, key)
    last = tasks[-1]
    eng.apply_stretch(last.id, 42.0)
    m_stretched = eng.makespan()
    eng.apply_retract(last.id)
    eng.undo()  # restore the retracted placement
    assert eng.makespan() == m_stretched
    assert eng.stretched[last.id] == 42.0


def test_apply_stretch_validation_and_replay_refusal():
    spec = A100
    tasks = generate_tasks(
        2, spec, workload("mixed", "wide", spec), seed=1, id_offset=740
    )
    from repro.core.repartition import Assignment

    asgn = Assignment(spec, {t.id: t for t in tasks}, {})
    eng = TimingEngine(asgn)
    key = spec.nodes[0].key
    eng.apply_append(tasks[0].id, key)
    with pytest.raises(ValueError, match="positive"):
        eng.apply_stretch(tasks[0].id, 0.0)
    # the replay reference models profiled durations only; runtime
    # corrections are a TimingEngine capability
    ref = ReplayEngine(asgn)
    ref.apply_append(tasks[0].id, key)
    with pytest.raises(NotImplementedError):
        ref.apply_stretch(tasks[0].id, 5.0)


def test_apply_cancel_marks_record_failed_and_undoes_exactly():
    spec = A100
    tasks = generate_tasks(
        4, spec, workload("mixed", "wide", spec), seed=3, id_offset=745
    )
    from repro.core.repartition import Assignment

    eng = TimingEngine(Assignment(spec, {t.id: t for t in tasks}, {}))
    key = spec.nodes[0].key
    for t in tasks:
        eng.apply_append(t.id, key)
    before = _snapshot(eng)
    m0 = eng.makespan()
    loser = tasks[0]
    eng.apply_cancel(loser.id, 2.5)
    # the cancelled occupancy record is truncated: successors move up
    sched = eng.schedule()
    rec = next(it for it in sched.items if it.task.id == loser.id)
    assert rec.failed and rec.corrected
    assert rec.duration == pytest.approx(2.5)
    assert eng.makespan() < m0
    live = [it for it in sched.items if not it.failed]
    assert loser.id not in {it.task.id for it in live}
    # cancel on top of cancel: latest truncation wins, undo unwinds both
    eng.apply_cancel(loser.id, 1.25)
    assert next(
        it for it in eng.schedule().items if it.task.id == loser.id
    ).duration == pytest.approx(1.25)
    eng.undo()
    assert next(
        it for it in eng.schedule().items if it.task.id == loser.id
    ).duration == pytest.approx(2.5)
    assert loser.id in eng.cancelled  # first cancel still holds
    eng.undo()
    assert _snapshot(eng) == before
    assert eng.makespan() == m0
    assert loser.id not in eng.cancelled
    assert all(not it.failed for it in eng.schedule().items)


def test_apply_credit_shrinks_to_remainder_and_undoes_exactly():
    spec = A100
    tasks = generate_tasks(
        3, spec, workload("mixed", "wide", spec), seed=6, id_offset=750
    )
    from repro.core.repartition import Assignment

    eng = TimingEngine(Assignment(spec, {t.id: t for t in tasks}, {}))
    key = spec.nodes[0].key
    for t in tasks:
        eng.apply_append(t.id, key)
    before = _snapshot(eng)
    m0 = eng.makespan()
    first = tasks[0]
    planned = first.times[spec.nodes[0].size]
    eng.apply_credit(first.id, 0.25 * planned)
    # checkpoint credit shrinks the record to its remainder; the task
    # stays LIVE (unlike cancel) and the chain behind it moves up
    sched = eng.schedule()
    rec = next(it for it in sched.items if it.task.id == first.id)
    assert not rec.failed and rec.corrected
    assert rec.duration == pytest.approx(0.75 * planned)
    assert eng.makespan() == pytest.approx(m0 - 0.25 * planned)
    eng.undo()
    assert _snapshot(eng) == before
    assert eng.makespan() == m0
    assert first.id not in eng.stretched


def test_apply_cancel_credit_validation_and_replay_refusal():
    spec = A100
    tasks = generate_tasks(
        2, spec, workload("mixed", "wide", spec), seed=2, id_offset=755
    )
    from repro.core.repartition import Assignment

    asgn = Assignment(spec, {t.id: t for t in tasks}, {})
    eng = TimingEngine(asgn)
    key = spec.nodes[0].key
    eng.apply_append(tasks[0].id, key)
    with pytest.raises(ValueError, match="positive"):
        eng.apply_cancel(tasks[0].id, 0.0)
    with pytest.raises(ValueError, match="positive"):
        eng.apply_credit(tasks[0].id, -1.0)
    # credit must leave a positive remainder: crediting the whole
    # duration (or more) would erase the placement instead of shrinking
    planned = tasks[0].times[spec.nodes[0].size]
    with pytest.raises(ValueError, match="remainder"):
        eng.apply_credit(tasks[0].id, planned)
    ref = ReplayEngine(asgn)
    ref.apply_append(tasks[0].id, key)
    with pytest.raises(NotImplementedError):
        ref.apply_cancel(tasks[0].id, 5.0)
    with pytest.raises(NotImplementedError):
        ref.apply_credit(tasks[0].id, 5.0)


# --- identity-cache safety + opcode-exhaustive undo ------------------------

@pytest.mark.parametrize("spec", SPECS)
def test_two_engines_same_spec_bit_identical(spec):
    """The observable half of the IdentityCache safety argument
    (timing.py): whether a derived-structure lookup hits or misses the
    identity-keyed cache, two engines built from the same spec and
    assignment produce bit-identical schedules — identity only gates
    recomputation, never the computed bytes."""
    import copy

    import numpy as np

    from repro.core.timing import _batch_spec_arrays

    tasks = generate_tasks(
        12, spec, workload("mixed", "wide", spec), seed=21, id_offset=760
    )
    fam = allocation_family(tasks, spec)
    assignment = list_schedule_allocation(tasks, fam[len(fam) // 2], spec)
    a = TimingEngine(assignment)
    b = TimingEngine(assignment)
    for flag in (True, False):
        assert a.makespan(flag) == b.makespan(flag)
        assert a.slice_end_times(flag) == b.slice_end_times(flag)
        assert a.node_end_times(flag) == b.node_end_times(flag)
    sa, sb = a.schedule(), b.schedule()
    assert sa.items == sb.items
    assert sa.reconfigs == sb.reconfigs
    # identical edit sequences stay bit-identical
    occupied = sorted(k for k, v in a.chains.items() if v)
    tid = a.chains[occupied[0]][0]
    dst = next(n.key for n in spec.nodes if n.key != occupied[0])
    for eng in (a, b):
        eng.apply_move(tid, dst=dst, src=occupied[0])
    assert a.makespan() == b.makespan()
    assert a.schedule().items == b.schedule().items
    # cache hit/miss parity, pinned directly: the second call for the
    # same anchor is a hit (the same tuple object); a deep copy of the
    # spec is a distinct anchor (forced miss) yet derives equal arrays
    first = _batch_spec_arrays(spec)
    assert _batch_spec_arrays(spec) is first
    fresh = _batch_spec_arrays(copy.deepcopy(spec))
    assert fresh is not first
    assert len(fresh) == len(first)
    for got, want in zip(fresh, first):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_undo_round_trip_covers_every_opcode():
    """Exhaustive apply_*/undo round trip, with the opcode set enumerated
    from the engine itself: every `kind == "..."` branch in undo() must
    be exercised by some driver below, and every apply_* method must have
    a driver.  A future opcode added without extending this test fails
    here, not in a confusing downstream search."""
    import ast as astmod
    import inspect
    import textwrap

    # opcodes undo() knows how to revert, read from its source
    undo_src = textwrap.dedent(inspect.getsource(TimingEngine.undo))
    undo_ops = {
        comp.value
        for node in astmod.walk(astmod.parse(undo_src))
        if isinstance(node, astmod.Compare)
        and isinstance(node.left, astmod.Name) and node.left.id == "kind"
        for comp in node.comparators
        if isinstance(comp, astmod.Constant) and isinstance(comp.value, str)
    }
    apply_ops = {
        name[len("apply_"):]
        for name in dir(TimingEngine) if name.startswith("apply_")
    }
    assert apply_ops == undo_ops, (
        "apply_* methods and undo() branches disagree — add the missing "
        "undo branch (or remove the dead one)"
    )

    spec = A100
    tasks = generate_tasks(
        10, spec, workload("mixed", "wide", spec), seed=11, id_offset=780
    )
    fam = allocation_family(tasks, spec)
    assignment = list_schedule_allocation(tasks, fam[0], spec)
    eng = TimingEngine(assignment)
    before = _snapshot(eng)
    before_stretched = dict(eng.stretched)
    before_times = {
        flag: (eng.makespan(flag), eng.slice_end_times(flag))
        for flag in (True, False)
    }
    before_sched = eng.schedule()

    def occupied():
        return sorted(k for k, v in eng.chains.items() if v)

    def spare():
        occ = set(occupied())
        return next(n.key for n in spec.nodes if n.key not in occ)

    def drive_move():
        src = occupied()[0]
        tid = eng.chains[src][0]
        eng.apply_move(tid, dst=spare(), src=src)

    def drive_swap():
        occ = occupied()
        if len(occ) < 2:  # single-chain layout cannot swap
            pytest.skip("allocation placed every task on one node")
        ka, kb = occ[0], occ[-1]
        eng.apply_swap(eng.chains[ka][0], eng.chains[kb][0])

    def drive_append():
        key = occupied()[0]
        tid = eng.chains[key][-1]
        eng.apply_extract(tid)
        eng.apply_append(tid, spare())

    def drive_extract_place():
        key = occupied()[0]
        tid = eng.chains[key][0]
        eng.apply_extract(tid)
        eng.apply_place(tid, spare())

    def drive_retract():
        key = occupied()[0]
        eng.apply_retract(eng.chains[key][-1], key)

    def drive_stretch():
        key = occupied()[0]
        eng.apply_stretch(eng.chains[key][0], 123.456)

    def drive_cancel():
        key = occupied()[0]
        eng.apply_cancel(eng.chains[key][0], 7.875)

    def drive_credit():
        key = occupied()[-1]
        tid = eng.chains[key][-1]
        begin, end = eng.task_begin_end(tid)
        eng.apply_credit(tid, (end - begin) * 0.5)

    drivers = {
        "move": drive_move,
        "swap": drive_swap,
        "append": drive_append,
        "extract": drive_extract_place,
        "place": drive_extract_place,
        "retract": drive_retract,
        "stretch": drive_stretch,
        "cancel": drive_cancel,
        "credit": drive_credit,
    }
    assert set(drivers) == apply_ops, (
        "a new apply_* opcode has no driver here — extend the round trip"
    )
    for op in sorted(drivers):
        drivers[op]()
    logged = {entry[0] for entry in eng._log}
    assert logged == undo_ops, (
        f"drivers exercised {sorted(logged)} but undo() handles "
        f"{sorted(undo_ops)}"
    )
    eng.undo_all()
    assert _snapshot(eng) == before
    assert dict(eng.stretched) == before_stretched
    after_times = {
        flag: (eng.makespan(flag), eng.slice_end_times(flag))
        for flag in (True, False)
    }
    assert after_times == before_times
    after_sched = eng.schedule()
    assert after_sched.items == before_sched.items
    assert after_sched.reconfigs == before_sched.reconfigs
