"""Tests for the scheduler contract analyzer (repro.analysis).

Three layers:

* **golden fixtures** — each checker has >=2 violating and >=2 clean
  snippets under ``tests/fixtures/analysis/``; the expected findings are
  pinned as exact ``(check, line, key)`` triples so a checker that
  drifts (new false positive, lost detection, changed fingerprint) fails
  loudly here before it fails confusingly in CI.
* **baseline mechanics** — load/apply/write round-trips, the
  empty-justification and duplicate-entry rejections, and the stale-entry
  split that makes an expired suppression a hard error.
* **meta** — the live ``src/repro/core/`` tree is clean modulo the
  committed baseline, and the CLI exit codes match (0 clean, 1 findings,
  2 usage).  This is the same invocation the CI ``contracts-lint`` job
  makes, so a local red here predicts the CI red.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import all_checkers, run_analysis
from repro.analysis.baseline import (
    BaselineEntry,
    BaselineError,
    apply_baseline,
    load_baseline,
    write_baseline,
)
from repro.analysis.framework import Finding, collect_files

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "fixtures" / "analysis"
BASELINE = REPO / "tools" / "contracts_baseline.json"


def _analyze(*relpaths: str) -> list[tuple[str, int, str]]:
    paths = [str(FIXTURES / rel) for rel in relpaths]
    findings = run_analysis(paths, all_checkers())
    return [(f.check, f.line, f.key) for f in findings]


# ---------------------------------------------------------------------------
# golden fixtures: exact expected findings per violating file
# ---------------------------------------------------------------------------

GOLDEN_BAD = {
    "determinism/bad_set_iteration.py": [
        ("determinism", 6, "set-iteration:free"),
        ("determinism", 13, "set-iteration:pending"),
        ("determinism", 14, "set-pop"),
        ("determinism", 19, "id-call"),
        ("determinism", 25, "set-ordered-dict:ready.values()"),
    ],
    "determinism/bad_unseeded_rng.py": [
        ("determinism", 9, "unseeded:random.Random"),
        ("determinism", 14, "global-rng:random.shuffle"),
        ("determinism", 19, "unseeded:default_rng"),
    ],
    "determinism/bad_wall_clock.py": [
        ("determinism", 8, "wall-clock:time.time"),
        ("determinism", 13, "wall-clock:datetime.now"),
    ],
    "engine_routing/bad_engine_internals.py": [
        ("engine-routing", 5, "internal:durs"),
        ("engine-routing", 9, "internal:_log"),
        ("engine-routing", 13, "internal:stretched"),
    ],
    "engine_routing/bad_replay_call.py": [
        ("engine-routing", 7, "call:replay"),
        ("engine-routing", 11, "call:replay#2"),
    ],
    "engine_routing/bad_unused_import.py": [
        ("engine-routing", 3, "unused-import:replay"),
    ],
    "frozen_surface/bad_mutate_config.py": [
        ("frozen-surface", 7, "mutate:SchedulerConfig.seed"),
        ("frozen-surface", 13, "mutate:SchedulerConfig.eps"),
        ("frozen-surface", 18, "setattr-bypass"),
    ],
    "frozen_surface/bad_mutate_plan.py": [
        ("frozen-surface", 6, "mutate:PlanResult.policy"),
        ("frozen-surface", 12, "mutate:PlanResult.makespan"),
    ],
    "pragmas/bad_stale.py": [
        ("pragma", 5, "stale:determinism"),
    ],
    "pragmas/bad_unjustified.py": [
        ("pragma", 7, "missing-justification:determinism"),
    ],
    "registry_conformance/bad_bad_shape.py": [
        ("registry-conformance", 17, "policy-missing-plan:StubPolicy"),
        ("registry-conformance", 24, "policy-shape:ShortPolicy.plan"),
        ("registry-conformance", 29, "evaluator-missing:MuteEvaluator"),
        ("registry-conformance", 36,
         "evaluator-shape:NarrowEvaluator.evaluate"),
    ],
    "registry_conformance/bad_unknown_field.py": [
        ("registry-conformance", 16, "unknown-field:max_refine_iters"),
        ("registry-conformance", 21, "unknown-field:epsilon"),
    ],
    "undo_completeness/bad_missing_branch.py": [
        ("undo-completeness", 16, "missing-undo:drop"),
        ("undo-completeness", 43, "arity:push"),
    ],
    "undo_completeness/bad_override.py": [
        ("undo-completeness", 14, "no-unknown-raise:BaseState"),
        ("undo-completeness", 24, "override:QuietOverride.apply_add"),
    ],
}

GOLDEN_CLEAN = [
    "determinism/clean_seeded_rng.py",
    "determinism/clean_sorted_sets.py",
    "engine_routing/clean_engine_api.py",
    "engine_routing/timing.py",
    "frozen_surface/clean_replace.py",
    "frozen_surface/policy.py",
    "pragmas/clean_justified.py",
    "registry_conformance/clean_policy.py",
    "registry_conformance/clean_unknown_config_type.py",
    "undo_completeness/clean_complete.py",
    "undo_completeness/clean_refusal.py",
]


@pytest.mark.parametrize("rel", sorted(GOLDEN_BAD), ids=lambda r: r)
def test_golden_bad_fixture(rel):
    expected = GOLDEN_BAD[rel]
    got = _analyze(rel)
    assert got == expected


@pytest.mark.parametrize("rel", GOLDEN_CLEAN, ids=lambda r: r)
def test_golden_clean_fixture(rel):
    assert _analyze(rel) == []


def test_every_checker_has_two_bad_and_two_clean_fixtures():
    """The fixture floor ISSUE asks for: >=2 violating and >=2 clean
    snippets per checker (pragma handling counts the pragmas/ dir)."""
    by_checker_bad: dict[str, int] = {}
    for rel in GOLDEN_BAD:
        by_checker_bad[rel.split("/")[0]] = \
            by_checker_bad.get(rel.split("/")[0], 0) + 1
    by_checker_clean: dict[str, int] = {}
    for rel in GOLDEN_CLEAN:
        by_checker_clean[rel.split("/")[0]] = \
            by_checker_clean.get(rel.split("/")[0], 0) + 1
    dirs = {
        "determinism", "engine_routing", "frozen_surface",
        "registry_conformance", "undo_completeness",
    }
    for d in dirs:
        assert by_checker_bad.get(d, 0) >= 2, d
        assert by_checker_clean.get(d, 0) >= 2, d
    # every fixture named above actually exists on disk
    for rel in list(GOLDEN_BAD) + GOLDEN_CLEAN:
        assert (FIXTURES / rel).is_file(), rel


def test_select_restricts_checkers():
    got = run_analysis(
        [str(FIXTURES / "determinism" / "bad_wall_clock.py")],
        all_checkers(),
        select=frozenset({"engine-routing"}),
    )
    assert got == []


def test_pragma_is_checker_scoped():
    """A [determinism] pragma does not suppress another checker's finding
    on the same line — and unrelated-check pragmas count as stale."""
    src = FIXTURES / "pragmas" / "bad_stale.py"
    findings = run_analysis([str(src)], all_checkers())
    assert [(f.check, f.key) for f in findings] == \
        [("pragma", "stale:determinism")]


def test_ordinal_fingerprints_are_stable():
    findings = run_analysis(
        [str(FIXTURES / "engine_routing" / "bad_replay_call.py")],
        all_checkers(),
    )
    keys = [f.key for f in findings]
    assert keys == ["call:replay", "call:replay#2"]
    # fingerprints are line-free: same file analyzed twice agrees
    again = run_analysis(
        [str(FIXTURES / "engine_routing" / "bad_replay_call.py")],
        all_checkers(),
    )
    assert [f.fingerprint for f in findings] == \
        [f.fingerprint for f in again]


def test_syntax_error_becomes_parse_finding(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def nope(:\n", encoding="utf-8")
    findings = run_analysis([str(bad)], all_checkers())
    assert [(f.check, f.key) for f in findings] == \
        [("parse", "syntax-error")]


def test_collect_files_sorted_and_deduplicated(tmp_path):
    (tmp_path / "b.py").write_text("", encoding="utf-8")
    (tmp_path / "a.py").write_text("", encoding="utf-8")
    sub = tmp_path / "__pycache__"
    sub.mkdir()
    (sub / "a.cpython-311.py").write_text("", encoding="utf-8")
    files = collect_files([str(tmp_path), str(tmp_path / "a.py")])
    names = [os.path.basename(f) for f in files]
    assert names == ["a.py", "b.py"]


# ---------------------------------------------------------------------------
# baseline mechanics
# ---------------------------------------------------------------------------

def _finding(check="determinism", path="x.py", key="set-pop", line=3):
    return Finding(
        check=check, contract="c", path=path, line=line,
        message="m", hint="h", key=key,
    )


def test_apply_baseline_splits_used_and_stale():
    findings = [_finding(key="set-pop"), _finding(key="id-call")]
    entries = [
        BaselineEntry("determinism", "x.py", "set-pop", "grandfathered"),
        BaselineEntry("determinism", "x.py", "gone", "was fixed"),
    ]
    out, used, stale = apply_baseline(findings, entries)
    assert [f.key for f in out] == ["id-call"]
    assert [e.key for e in used] == ["set-pop"]
    assert [e.key for e in stale] == ["gone"]


def test_load_baseline_rejects_empty_justification(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps({
        "version": 1,
        "entries": [
            {"check": "determinism", "path": "x.py", "key": "k",
             "justification": "   "},
        ],
    }), encoding="utf-8")
    with pytest.raises(BaselineError, match="empty justification"):
        load_baseline(str(p))


def test_load_baseline_rejects_duplicates_and_bad_version(tmp_path):
    p = tmp_path / "baseline.json"
    entry = {"check": "c", "path": "p", "key": "k", "justification": "j"}
    p.write_text(json.dumps({"version": 1, "entries": [entry, entry]}),
                 encoding="utf-8")
    with pytest.raises(BaselineError, match="duplicate"):
        load_baseline(str(p))
    p.write_text(json.dumps({"version": 99, "entries": []}),
                 encoding="utf-8")
    with pytest.raises(BaselineError, match="version"):
        load_baseline(str(p))


def test_write_baseline_round_trips(tmp_path):
    p = tmp_path / "baseline.json"
    findings = [_finding(key="a"), _finding(key="b")]
    write_baseline(str(p), findings, justification="FIXME: justify")
    entries = load_baseline(str(p))
    assert [e.key for e in entries] == ["a", "b"]
    out, used, stale = apply_baseline(findings, entries)
    assert out == [] and len(used) == 2 and stale == []


# ---------------------------------------------------------------------------
# meta: the live tree and the CLI
# ---------------------------------------------------------------------------

def test_live_core_tree_clean_modulo_baseline():
    """src/repro/core carries no contract violations beyond the committed
    baseline, and every baseline entry still matches a live finding."""
    findings = run_analysis([str(REPO / "src" / "repro" / "core")],
                            all_checkers())
    # re-root fingerprints: the analyzer stores paths as given
    entries = load_baseline(str(BASELINE))
    rel = [
        Finding(
            check=f.check, contract=f.contract,
            path=os.path.relpath(f.path, str(REPO)).replace(os.sep, "/"),
            line=f.line, message=f.message, hint=f.hint, key=f.key,
        )
        for f in findings
    ]
    out, used, stale = apply_baseline(rel, entries)
    assert out == [], "\n".join(f.render() for f in out)
    assert stale == [], [e.fingerprint for e in stale]
    for e in entries:
        assert e.justification.strip(), e.fingerprint


def _run_cli(*args: str, cwd: str | None = None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=cwd or str(REPO), env=env,
        capture_output=True, text=True, timeout=120,
    )


def test_cli_exit_codes():
    bad = str(FIXTURES / "determinism" / "bad_wall_clock.py")
    clean = str(FIXTURES / "determinism" / "clean_seeded_rng.py")
    assert _run_cli(bad, "--no-baseline").returncode == 1
    assert _run_cli(clean, "--no-baseline").returncode == 0
    assert _run_cli("no/such/path.txt").returncode == 2
    # the CI invocation: shipped tree + committed baseline
    proc = _run_cli("src/repro/core")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


def test_cli_json_format_and_list_checkers():
    bad = str(FIXTURES / "engine_routing" / "bad_replay_call.py")
    proc = _run_cli(bad, "--no-baseline", "--format", "json")
    assert proc.returncode == 1
    payload = json.loads(proc.stdout)
    assert [f["key"] for f in payload["findings"]] == \
        ["call:replay", "call:replay#2"]
    listing = _run_cli("--list-checkers")
    assert listing.returncode == 0
    for cid in ("determinism", "engine-routing", "undo-completeness",
                "frozen-surface", "registry-conformance"):
        assert cid in listing.stdout


def test_cli_main_in_process(tmp_path, capsys):
    """Drive the CLI entry point in-process (argument handling, baseline
    resolution, --write-baseline) — the subprocess tests above pin the
    real exit codes, this pins the branches for coverage."""
    from repro.analysis.__main__ import main

    bad = str(FIXTURES / "determinism" / "bad_wall_clock.py")
    clean = str(FIXTURES / "determinism" / "clean_seeded_rng.py")

    assert main([clean, "--no-baseline"]) == 0
    assert "clean" in capsys.readouterr().out
    assert main([bad, "--no-baseline"]) == 1
    assert "wall-clock" in capsys.readouterr().out

    # an explicitly-given but missing baseline is a hard usage error;
    # the default one being absent is tolerated
    missing = str(tmp_path / "nope.json")
    assert main([bad, "--baseline", missing]) == 2
    assert main([bad, "--baseline", str(tmp_path / "also_missing.json"),
                 "--no-baseline"]) == 1
    capsys.readouterr()

    # --write-baseline emits FIXME entries the loader then rejects on use
    out = str(tmp_path / "baseline.json")
    assert main([bad, "--write-baseline", "--baseline", out]) == 0
    entries = load_baseline(out)
    assert len(entries) == 2
    assert all(e.justification == "FIXME" for e in entries)
    # ... and applying it suppresses both findings
    assert main([bad, "--baseline", out]) == 0
    capsys.readouterr()

    # a baseline whose finding is gone is stale -> nonzero
    assert main([clean, "--baseline", out]) == 1
    assert "stale" in capsys.readouterr().out

    assert main(["--list-checkers"]) == 0
    listed = capsys.readouterr().out
    assert "determinism" in listed and "frozen-surface" in listed

    with pytest.raises(SystemExit):
        main([bad, "--select", "no-such-checker"])
    with pytest.raises(SystemExit):
        main([])
    capsys.readouterr()

    # json format path
    assert main([bad, "--no-baseline", "--format", "json"]) == 1
    payload = json.loads(capsys.readouterr().out)
    assert len(payload["findings"]) == 2
