"""Step bundles lower and run on a 1-device mesh for smoke configs
(the production-mesh equivalents are covered by the 512-device dry-run)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SMOKES
from repro.launch.mesh import mesh_shape_dict
from repro.models.config import ShapeConfig, input_specs
from repro.models.model import build_model
from repro.parallel.sharding import make_rules
from repro.parallel.steps import (
    init_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)


@pytest.mark.parametrize("name", ["qwen2-moe-a2.7b", "gemma3-12b",
                                  "zamba2-2.7b", "whisper-small"])
def test_train_bundle_runs(name):
    cfg = SMOKES[name]
    model = build_model(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules(cfg, mesh_shape_dict(mesh), fsdp=False)
    shape = ShapeConfig("t", 32, 2, "train")
    bundle = make_train_step(model, rules, mesh, shape)
    with mesh:
        fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings,
                     donate_argnums=bundle.donate_argnums)
        state = init_train_state(model, jax.random.key(0))
        batch = {
            "tokens": jnp.ones((2, 32), jnp.int32),
            "labels": jnp.ones((2, 32), jnp.int32),
        }
        if cfg.is_encoder_decoder:
            batch["frames"] = jnp.zeros(
                (2, cfg.encoder_frames, cfg.d_model), jnp.bfloat16
            )
        state, metrics = fn(state, batch)
        assert float(metrics["loss"]) > 0
        assert int(metrics["step"]) == 1
        state, metrics = fn(state, batch)
        assert int(metrics["step"]) == 2


@pytest.mark.parametrize("name", ["qwen2.5-3b", "xlstm-350m"])
def test_prefill_decode_bundles_run(name):
    cfg = SMOKES[name]
    model = build_model(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules(cfg, mesh_shape_dict(mesh), fsdp=False)
    shape_p = ShapeConfig("p", 32, 2, "prefill")
    shape_d = ShapeConfig("d", 32, 2, "decode")
    pre = make_prefill_step(model, rules, mesh, shape_p)
    dec = make_decode_step(model, rules, mesh, shape_d)
    with mesh:
        params = model.init(jax.random.key(0))
        pfn = jax.jit(pre.fn, in_shardings=pre.in_shardings,
                      out_shardings=pre.out_shardings)
        logits, cache = pfn(params, {"tokens": jnp.ones((2, 32), jnp.int32)})
        dfn = jax.jit(dec.fn, in_shardings=dec.in_shardings,
                      out_shardings=dec.out_shardings,
                      donate_argnums=dec.donate_argnums)
        logits2, cache2 = dfn(params, cache, jnp.ones((2, 1), jnp.int32))
        assert logits2.shape == (2, 1, cfg.padded_vocab())


def test_microbatched_train_step_matches_full_batch():
    cfg = SMOKES["gemma-2b"]
    model = build_model(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules(cfg, mesh_shape_dict(mesh), fsdp=False)
    shape = ShapeConfig("t", 32, 8, "train")
    batch = {
        "tokens": jax.random.randint(jax.random.key(0), (8, 32), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.key(1), (8, 32), 0,
                                     cfg.vocab_size),
    }
    losses = {}
    with mesh:
        for mb in (1, 4):
            b = make_train_step(model, rules, mesh, shape, microbatches=mb)
            fn = jax.jit(b.fn, in_shardings=b.in_shardings,
                         out_shardings=b.out_shardings)
            state = init_train_state(model, jax.random.key(0))
            state, metrics = fn(state, batch)
            losses[mb] = float(metrics["loss"])
    assert losses[1] == pytest.approx(losses[4], rel=1e-2)
