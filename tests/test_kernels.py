"""Per-kernel allclose vs the pure-jnp oracles (interpret mode on CPU),
sweeping shapes, dtypes and feature flags."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.slstm_cell.kernel import slstm_cell
from repro.kernels.slstm_cell.ref import slstm_cell_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def _tol(dtype):
    return 3e-2 if dtype == jnp.bfloat16 else 2e-4


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,skv,h,kv,hd,causal,window,softcap,bq,bk",
    [
        (2, 128, 128, 4, 2, 32, True, 0, 0.0, 64, 64),
        (1, 256, 256, 2, 2, 64, True, 48, 0.0, 64, 64),
        (1, 128, 128, 4, 1, 32, False, 0, 0.0, 64, 32),
        (1, 128, 128, 2, 2, 32, True, 0, 30.0, 32, 64),
        (2, 64, 64, 8, 8, 16, True, 0, 0.0, 32, 32),
        (1, 64, 64, 4, 4, 128, True, 0, 0.0, 64, 64),
    ],
)
def test_flash_attention_vs_ref(b, sq, skv, h, kv, hd, causal, window,
                                softcap, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, skv, kv, hd), dtype)
    v = jax.random.normal(ks[2], (b, skv, kv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, bq=bq, bk=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window,
                        softcap=softcap)
    err = jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))
    assert float(err) < _tol(dtype), float(err)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,l,h,kv,hd,softcap,bk,frac",
    [
        (2, 256, 8, 2, 32, 0.0, 64, 0.7),
        (1, 512, 4, 4, 64, 0.0, 128, 0.5),
        (1, 256, 8, 1, 32, 30.0, 64, 0.9),
        (2, 128, 16, 4, 16, 0.0, 32, 1.0),
    ],
)
def test_decode_attention_vs_ref(b, l, h, kv, hd, softcap, bk, frac, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, l, kv, hd), dtype)
    v = jax.random.normal(ks[2], (b, l, kv, hd), dtype)
    valid = jnp.arange(l) < int(l * frac)
    out = decode_attention(q, k, v, valid, softcap=softcap, bk=bk,
                           interpret=True)
    ref = decode_attention_ref(q, k, v, valid, softcap=softcap)
    err = jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))
    assert float(err) < _tol(dtype), float(err)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,p,n,chunk",
    [
        (2, 128, 4, 16, 8, 32),
        (1, 256, 2, 64, 64, 64),
        (2, 64, 8, 32, 16, 16),
    ],
)
def test_ssd_scan_vs_ref(b, s, h, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, s, n), dtype)
    cm = jax.random.normal(ks[4], (b, s, n), dtype)
    out = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    ref = ssd_scan_ref(x, dt, a, bm, cm, chunk=chunk)
    scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32)))) + 1e-6
    err = float(
        jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))
    ) / scale
    assert err < _tol(dtype), err


def test_flash_attention_matches_model_attention_path():
    """The kernel agrees with the model's XLA attention layer."""
    from repro.models import layers
    from repro.models.config import ArchConfig

    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                     head_dim=16)
    p = layers.attention_init(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, 64), jnp.float32)

    q, k, v = layers._qkv(p, cfg, x)
    pos = jnp.arange(64)[None, :]
    q = layers.rope(q, pos, cfg.rope_theta)
    k = layers.rope(k, pos, cfg.rope_theta)
    out_kernel = flash_attention(q, k, v, causal=True, bq=32, bk=32,
                                 interpret=True)
    out_ref = attention_ref(q, k, v, causal=True)
    err = jnp.max(jnp.abs(out_kernel - out_ref))
    assert float(err) < 1e-4


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,d,chunk", [
    (2, 64, 3, 16, 32), (1, 128, 2, 32, 64), (2, 96, 4, 8, 16),
])
def test_slstm_cell_vs_ref(b, t, h, d, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(5), 8)
    zx, ix, fx, ox = (
        jax.random.normal(ks[i], (b, t, h, d), dtype) for i in range(4)
    )
    rz, ri, rf, ro = (
        jax.random.normal(ks[4 + i], (h, d, d), dtype) * 0.2
        for i in range(4)
    )
    out = slstm_cell(zx, ix, fx, ox, rz, ri, rf, ro, chunk=chunk,
                     interpret=True)
    ref = slstm_cell_ref(zx, ix, fx, ox, rz, ri, rf, ro)
    err = jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))
    assert float(err) < (5e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("spec_name", ["A30", "A100", "TPU"])
@pytest.mark.parametrize("C,L,integer", [
    (1, 1, False), (3, 7, True), (8, 21, False), (13, 40, True),
])
def test_chains_makespan_vs_ref_bit_exact(spec_name, C, L, integer):
    """Unlike the model kernels, the scheduler kernel's contract is
    bit-exactness, not a tolerance: phase-2 winner selection breaks EPS
    ties by index, so a single ulp could flip a winner."""
    import numpy as np

    from repro.core.device_spec import A30, A100, TPU_POD_256
    from repro.kernels.chains_makespan.ops import chains_makespan_batch_pallas
    from repro.kernels.chains_makespan.ref import chains_makespan_batch_ref

    spec = {"A30": A30, "A100": A100, "TPU": TPU_POD_256}[spec_name]
    N = len(spec.nodes)
    rng = np.random.default_rng(C * 31 + L)
    lens = rng.integers(0, L + 1, size=(C, N)).astype(np.int32)
    lens[0] = 0  # empty candidate: makespan 0 by definition
    durs = np.zeros((C, N, L))
    for c in range(C):
        for j in range(N):
            k = lens[c, j]
            vals = rng.uniform(0.5, 4.0, size=k)
            if integer:  # tie-dense chains stress the (when, seq) order
                vals = np.floor(vals * 2.0) / 2.0
            durs[c, j, :k] = vals
    ref = chains_makespan_batch_ref(spec, durs, lens)
    out = chains_makespan_batch_pallas(spec, durs, lens, interpret=True)
    assert np.array_equal(ref, out)


def test_chains_makespan_pallas_empty_batch():
    import numpy as np

    from repro.core.device_spec import A100
    from repro.kernels.chains_makespan.ops import chains_makespan_batch_pallas

    N = len(A100.nodes)
    out = chains_makespan_batch_pallas(
        A100, np.zeros((0, N, 1)), np.zeros((0, N), dtype=np.int32),
        interpret=True,
    )
    assert out.shape == (0,)
