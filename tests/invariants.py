"""Reusable schedule-invariant harness.

``assert_valid_schedule(schedule, spec)`` is an *independent* checker of
the paper's feasibility model — it re-derives every constraint from the
raw ``(task, node, begin)`` triples instead of delegating to
``repro.core.problem.validate_schedule``, so the two act as cross-checks
of each other.  It is the recommended harness for new policies: any
registered policy's output, and any :class:`SchedulingService` flush
sequence, must pass it (see ``tests/test_invariants.py``).

Checked invariants:

1. **tree membership & molding** — every placement sits on a node of the
   spec's repartitioning tree and is molded to exactly that node's size
   (with the task's profile defined at it);
2. **no slice overlap** — placements whose instances block a common
   ``(tree, slice)`` cell never overlap in time;
3. **partition legality per DeviceSpec** — at every placement start the
   set of co-running instances is a feasible instance set
   (pairwise-disjoint tree nodes = a sub-partition, MIG property P2),
   verified through ``spec.is_feasible_instance_set`` rather than
   implied from 2;
4. **causal release floors** — with ``floors={task_id: t}`` (e.g. the
   serving facade's flush decision times) no task begins before its
   floor;
5. **no preemption** — each task appears exactly once (one contiguous
   interval of exactly its profile duration; a preempted task would need
   two items), and with ``tasks`` given, the scheduled ids match the
   batch exactly.
"""

from repro.core.problem import EPS


class InvariantViolation(AssertionError):
    """A schedule broke one of the serving/feasibility invariants."""


def _fail(msg: str) -> None:
    raise InvariantViolation(msg)


def assert_valid_schedule(schedule, spec, *, tasks=None, floors=None) -> None:
    """Assert the invariants above; raises :class:`InvariantViolation`.

    Args:
      schedule: a :class:`repro.core.problem.Schedule`.
      spec: the :class:`repro.core.device_spec.DeviceSpec` it must obey
        (checked against ``spec``, not ``schedule.spec`` — a schedule
        smuggling foreign nodes must fail).
      tasks: optional batch; when given, scheduled ids must match it.
      floors: optional ``{task_id: time}`` causal floors (flush decision
        times in the serving model).
    """
    node_index = spec.node_index

    # 1 + 5a: membership, molding, duration honesty, single placement
    seen: dict[int, object] = {}
    for it in schedule.items:
        tid = it.task.id
        if tid in seen:
            _fail(f"task {tid} scheduled more than once (preemption or "
                  f"duplication)")
        seen[tid] = it
        node = node_index.get(it.node.key)
        if node is None:
            _fail(f"task {tid} placed on {it.node}, not a node of "
                  f"{spec.name}'s repartitioning tree")
        if it.size != it.node.size:
            _fail(f"task {tid} molded to size {it.size} but placed on "
                  f"size-{it.node.size} instance {it.node}")
        if it.size not in it.task.times:
            _fail(f"task {tid} has no profile entry for size {it.size}")
        if abs((it.end - it.begin) - it.task.times[it.size]) > 1e-6:
            _fail(f"task {tid} runs {it.end - it.begin}s, profile says "
                  f"{it.task.times[it.size]}s (preempted or stretched)")
        if it.begin < -EPS:
            _fail(f"task {tid} begins before time zero: {it.begin}")

    # 5b: the batch is covered exactly
    if tasks is not None:
        want = sorted(t.id for t in tasks)
        got = sorted(seen)
        if want != got:
            _fail(f"scheduled ids {got} != batch ids {want}")

    # 4: causal floors
    if floors:
        for tid, floor in floors.items():
            it = seen.get(tid)
            if it is not None and it.begin < floor - EPS:
                _fail(f"task {tid} begins at {it.begin} before its causal "
                      f"floor {floor} (placed before its flush decision)")

    # 2: no overlap on any blocked (tree, slice) cell
    per_cell: dict[tuple, list] = {}
    for it in schedule.items:
        for cell in it.node.blocked_cells:
            per_cell.setdefault(cell, []).append(it)
    for cell, lst in per_cell.items():
        lst.sort(key=lambda it: (it.begin, it.end))
        for a, b in zip(lst, lst[1:]):
            if a.end > b.begin + EPS:
                _fail(f"tasks {a.task.id} and {b.task.id} overlap on slice "
                      f"{cell}: [{a.begin:.3f},{a.end:.3f}) vs "
                      f"[{b.begin:.3f},{b.end:.3f})")

    # 3: partition legality at every placement start — the co-running
    # instance set must be a feasible sub-partition of the device
    items = sorted(schedule.items, key=lambda it: (it.begin, it.end))
    for it in items:
        t = it.begin
        running = {
            o.node.key: o.node for o in items
            if o.begin <= t + EPS and o.end > t + EPS
        }
        if not spec.is_feasible_instance_set(list(running.values())):
            _fail(f"at t={t:.3f} the running instances "
                  f"{sorted(running)} are not a valid sub-partition of "
                  f"{spec.name}")


def service_floors(svc) -> dict[int, float]:
    """Causal floors for a :class:`SchedulingService`'s combined schedule:
    each task's *first* flush decision time (a re-planned task is pulled
    back only by later decisions, so its placement — on either the
    re-planning chain or the never-replanned shadow — begins no earlier
    than the first decision that carried it)."""
    floors: dict[int, float] = {}
    for d in svc.stats.decisions:
        if d.task_id not in floors:
            floors[d.task_id] = d.decided_at
    return floors


__all__ = ["InvariantViolation", "assert_valid_schedule", "service_floors"]
