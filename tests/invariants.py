"""Reusable schedule-invariant harness.

``assert_valid_schedule(schedule, spec)`` is an *independent* checker of
the paper's feasibility model — it re-derives every constraint from the
raw ``(task, node, begin)`` triples instead of delegating to
``repro.core.problem.validate_schedule``, so the two act as cross-checks
of each other.  It is the recommended harness for new policies: any
registered policy's output, and any :class:`SchedulingService` flush
sequence, must pass it (see ``tests/test_invariants.py``).

Checked invariants:

1. **tree membership & molding** — every placement sits on a node of the
   spec's repartitioning tree and is molded to exactly that node's size
   (with the task's profile defined at it);
2. **no slice overlap** — placements whose instances block a common
   ``(tree, slice)`` cell never overlap in time;
3. **partition legality per DeviceSpec** — at every placement start the
   set of co-running instances is a feasible instance set
   (pairwise-disjoint tree nodes = a sub-partition, MIG property P2),
   verified through ``spec.is_feasible_instance_set`` rather than
   implied from 2;
4. **causal release floors** — with ``floors={task_id: t}`` (e.g. the
   serving facade's flush decision times) no task begins before its
   floor;
5. **no preemption** — each task appears exactly once (one contiguous
   interval of exactly its profile duration; a preempted task would need
   two items), and with ``tasks`` given, the scheduled ids match the
   batch exactly.

Runtime feedback relaxes two of these in a controlled way: *failed*
attempt records (``it.failed``) are occupancy slabs, not placements —
they are excluded from exactly-once coverage (the retry is the live
placement) — and *corrected* records (``it.corrected``, i.e. a runtime
``end_override``) are exempt from profile-duration honesty.  Two
corrected records may also overlap each other (a straggler stretch can
race a completion that was already reported on a neighbouring cell —
runtime truth is recorded, never rewritten); a *planned* record
overlapping anything is still a violation.

``assert_fault_invariants(svc)`` adds the fault-tolerance layer on a
drained :class:`SchedulingService`: no live placement on a quarantined
device inside its outage window, no live record spanning a loss instant
(running attempts must have been failed), and every retried attempt
begins at or after its backoff release.
"""

from repro.core.problem import EPS


def _is_failed(it) -> bool:
    return bool(getattr(it, "failed", False))


def _is_corrected(it) -> bool:
    return bool(getattr(it, "corrected", False))


class InvariantViolation(AssertionError):
    """A schedule broke one of the serving/feasibility invariants."""


def _fail(msg: str) -> None:
    raise InvariantViolation(msg)


def assert_valid_schedule(schedule, spec, *, tasks=None, floors=None) -> None:
    """Assert the invariants above; raises :class:`InvariantViolation`.

    Args:
      schedule: a :class:`repro.core.problem.Schedule`.
      spec: the :class:`repro.core.device_spec.DeviceSpec` it must obey
        (checked against ``spec``, not ``schedule.spec`` — a schedule
        smuggling foreign nodes must fail).  A
        :class:`~repro.core.cluster.ClusterSpec` is accepted too: items
        are split by owning device (via ``tree_device``) and each
        device's slice is checked under its own spec, with the
        exactly-once and batch-coverage checks applied pool-wide.
      tasks: optional batch; when given, scheduled ids must match it.
      floors: optional ``{task_id: time}`` causal floors (flush decision
        times in the serving model).
    """
    if hasattr(spec, "devices"):  # ClusterSpec: per-device + pool-wide
        tree_dev = spec.tree_device
        groups: dict[int, list] = {}
        for it in schedule.items:
            dev = tree_dev.get(it.node.tree)
            if dev is None:
                _fail(f"task {it.task.id} placed on tree {it.node.tree}, "
                      f"owned by no device of pool {spec.name}")
            groups.setdefault(dev, []).append(it)
        seen_pool: dict[int, object] = {}
        for dev_idx in sorted(groups):
            items = groups[dev_idx]
            sub = type("_Items", (), {"items": items})()
            assert_valid_schedule(sub, spec.devices[dev_idx], floors=floors)
            for it in items:
                if not _is_failed(it):
                    if it.task.id in seen_pool:
                        _fail(f"task {it.task.id} scheduled on two devices "
                              f"of pool {spec.name}")
                    seen_pool[it.task.id] = it
        if tasks is not None:
            want = sorted(t.id for t in tasks)
            got = sorted(seen_pool)
            if want != got:
                _fail(f"scheduled ids {got} != batch ids {want}")
        return
    node_index = spec.node_index

    # 1 + 5a: membership, molding, duration honesty, single placement
    seen: dict[int, object] = {}
    for it in schedule.items:
        tid = it.task.id
        if not _is_failed(it):
            if tid in seen:
                _fail(f"task {tid} scheduled more than once (preemption or "
                      f"duplication)")
            seen[tid] = it
        node = node_index.get(it.node.key)
        if node is None:
            _fail(f"task {tid} placed on {it.node}, not a node of "
                  f"{spec.name}'s repartitioning tree")
        if it.size != it.node.size:
            _fail(f"task {tid} molded to size {it.size} but placed on "
                  f"size-{it.node.size} instance {it.node}")
        if it.size not in it.task.times:
            _fail(f"task {tid} has no profile entry for size {it.size}")
        if _is_corrected(it) or _is_failed(it):
            if it.end < it.begin - EPS:
                _fail(f"task {tid}'s corrected record ends at {it.end} "
                      f"before it begins at {it.begin}")
        elif abs((it.end - it.begin) - it.task.times[it.size]) > 1e-6:
            _fail(f"task {tid} runs {it.end - it.begin}s, profile says "
                  f"{it.task.times[it.size]}s (preempted or stretched)")
        if it.begin < -EPS:
            _fail(f"task {tid} begins before time zero: {it.begin}")

    # 5b: the batch is covered exactly
    if tasks is not None:
        want = sorted(t.id for t in tasks)
        got = sorted(seen)
        if want != got:
            _fail(f"scheduled ids {got} != batch ids {want}")

    # 4: causal floors
    if floors:
        for tid, floor in floors.items():
            it = seen.get(tid)
            if it is not None and it.begin < floor - EPS:
                _fail(f"task {tid} begins at {it.begin} before its causal "
                      f"floor {floor} (placed before its flush decision)")

    # 2: no overlap on any blocked (tree, slice) cell
    per_cell: dict[tuple, list] = {}
    for it in schedule.items:
        for cell in it.node.blocked_cells:
            per_cell.setdefault(cell, []).append(it)
    for cell, lst in per_cell.items():
        lst.sort(key=lambda it: (it.begin, it.end))
        for i, a in enumerate(lst):
            for b in lst[i + 1:]:
                if a.end <= b.begin + EPS:
                    break
                if _is_corrected(a) and _is_corrected(b):
                    continue  # two runtime-truth records may race
                _fail(f"tasks {a.task.id} and {b.task.id} overlap on slice "
                      f"{cell}: [{a.begin:.3f},{a.end:.3f}) vs "
                      f"[{b.begin:.3f},{b.end:.3f})")

    # 3: partition legality at every placement start — the co-running
    # instance set must be a feasible sub-partition of the device
    items = sorted(schedule.items, key=lambda it: (it.begin, it.end))
    for it in items:
        t = it.begin
        running: dict = {}
        for o in items:
            if o.begin <= t + EPS and o.end > t + EPS:
                running.setdefault(o.node.key, []).append(o)
        nodes = [lst[0].node for lst in running.values()]
        if not spec.is_feasible_instance_set(nodes):
            # sanctioned only if every conflicting node pair is backed
            # exclusively by corrected (runtime-truth) records
            for ka, la in running.items():
                ca = set(la[0].node.blocked_cells)
                for kb, lb in running.items():
                    if kb <= ka or not ca & set(lb[0].node.blocked_cells):
                        continue
                    if all(_is_corrected(o) for o in la) \
                            and all(_is_corrected(o) for o in lb):
                        continue  # a feedback race, not a real partition
                    _fail(f"at t={t:.3f} the running instances "
                          f"{sorted(running)} are not a valid "
                          f"sub-partition of {spec.name}")


def service_floors(svc) -> dict[int, float]:
    """Causal floors for a :class:`SchedulingService`'s combined schedule:
    each task's *first* flush decision time (a re-planned task is pulled
    back only by later decisions, so its placement — on either the
    re-planning chain or the never-replanned shadow — begins no earlier
    than the first decision that carried it)."""
    floors: dict[int, float] = {}
    for d in svc.stats.decisions:
        if d.task_id not in floors:
            floors[d.task_id] = d.decided_at
    return floors


def shard_floors(sharded) -> list[dict[int, float]]:
    """Causal floors for a
    :class:`~repro.core.sharded.ShardedSchedulingService`, one dict per
    shard: each task's fast-path submit stamp folded under the owning
    shard's flush decision floors.  The sharded fast path admits and
    queues without planning, so the *submit* stamp is the earliest
    instant the system knew of the task — nothing may begin before it,
    and the inner flush decision (always >= the stamp after inbox
    forwarding) only tightens the floor.  Feed each dict to
    ``assert_valid_schedule(floors=...)`` against the matching entry of
    ``sharded.shard_schedules()``."""
    stamps = sharded.admission_stamps()
    out: list[dict[int, float]] = []
    for inner in sharded.shard_services:
        floors = service_floors(inner)
        for tid, stamp in stamps.items():
            if tid in floors and floors[tid] < stamp - EPS:
                _fail(f"task {tid}'s flush decision at {floors[tid]} "
                      f"precedes its sharded submit stamp {stamp}")
            if tid in floors:
                floors[tid] = max(floors[tid], stamp)
        out.append(floors)
    return out


def assert_fault_invariants(svc) -> None:
    """Fault-tolerance invariants of a (preferably drained)
    :class:`SchedulingService`:

    * **quarantine is honoured** — no live (non-failed) record on a lost
      device begins inside its outage window, and none spans the loss
      instant (an attempt running at the loss must have been failed);
    * **backoff floors** — each retried attempt's live placement begins
      no earlier than its latest retry release;
    * **no stranding** — every task withdrawn by an outage is either
      live again in the combined schedule, permanently failed, or
      explicitly rejected at drain;
    * **backup-attempt exclusivity** — every resolved speculation race
      names exactly one winner (``"primary"``, ``"backup"`` or
      ``"cancelled"``), no backup id survives as a live record after its
      race resolved, and a task never has two simultaneously-unresolved
      races;
    * **checkpoint-credit monotonicity** — per task, the banked progress
      fraction is strictly increasing in event order, stays inside
      ``(0, 1)``, and every grant carries positive credit seconds —
      replayed failure paths can never double-count credit.
    """
    items = [it for seg in svc.mb.segments for it in seg.items]
    live = {}
    for it in items:
        if not _is_failed(it):
            live[it.task.id] = it

    if svc.stats.outages:
        if svc.cluster is None:
            _fail("outages recorded on a single-device service")
        tree_dev = svc.cluster.tree_device
        for ev in svc.stats.outages:
            hi = ev.recovered_at if ev.recovered_at is not None else float(
                "inf")
            for it in items:
                if tree_dev[it.node.tree] != ev.device or _is_failed(it):
                    continue
                if ev.lost_at - EPS <= it.begin and it.begin < hi - EPS:
                    _fail(f"task {it.task.id} begins at {it.begin} on "
                          f"device {ev.device} inside its outage window "
                          f"[{ev.lost_at}, {hi})")
                if it.begin < ev.lost_at - EPS \
                        and it.end > ev.lost_at + EPS:
                    _fail(f"task {it.task.id} spans device {ev.device}'s "
                          f"loss at {ev.lost_at} without having been "
                          f"failed: [{it.begin}, {it.end})")

    latest_release: dict[int, float] = {}
    for ev in svc.stats.retries:
        latest_release[ev.task_id] = max(
            latest_release.get(ev.task_id, 0.0), ev.release)
    for tid, release in latest_release.items():
        it = live.get(tid)
        if it is not None and it.begin < release - EPS:
            _fail(f"retried task {tid} begins at {it.begin} before its "
                  f"backoff release {release}")

    resolved = (set(live) | set(svc.stats.failed)
                | set(svc.stats.rejected) | svc.completions.keys())
    for ev in svc.stats.outages:
        stranded = set(ev.withdrawn) - resolved
        if stranded:
            _fail(f"tasks {sorted(stranded)} withdrawn by device "
                  f"{ev.device}'s outage were never re-placed, failed, "
                  f"or rejected")

    # backup-attempt exclusivity
    specs = getattr(svc.stats, "speculations", ())
    open_races: set[int] = set()
    for ev in specs:
        if ev.winner is None:
            if ev.task_id in open_races:
                _fail(f"task {ev.task_id} has two unresolved speculation "
                      f"races at once")
            open_races.add(ev.task_id)
            continue
        if ev.winner not in ("primary", "backup", "cancelled"):
            _fail(f"speculation race for task {ev.task_id} resolved with "
                  f"unknown winner {ev.winner!r}")
        if ev.resolved_at is None or ev.resolved_at < ev.at - EPS:
            _fail(f"speculation race for task {ev.task_id} resolved at "
                  f"{ev.resolved_at} before it launched at {ev.at}")
        if ev.backup_id in live:
            _fail(f"backup attempt {ev.backup_id} of task {ev.task_id} "
                  f"is still a live record after its race resolved "
                  f"({ev.winner!r} won)")
        if ev.winner == "backup" and ev.task_id not in svc.completions:
            _fail(f"backup of task {ev.task_id} won its race but the "
                  f"task has no reported completion")

    # checkpoint-credit monotonicity
    progress: dict[int, float] = {}
    for ev in getattr(svc.stats, "checkpoints", ()):
        if not ev.credit_s > 0.0:
            _fail(f"checkpoint grant for task {ev.task_id} carries "
                  f"non-positive credit {ev.credit_s}")
        if not 0.0 < ev.progress < 1.0:
            _fail(f"checkpoint progress {ev.progress} of task "
                  f"{ev.task_id} is outside (0, 1)")
        prev = progress.get(ev.task_id)
        if prev is not None and ev.progress <= prev + 1e-12:
            _fail(f"checkpoint progress of task {ev.task_id} did not "
                  f"increase: {prev} -> {ev.progress} (double-counted "
                  f"credit?)")
        progress[ev.task_id] = ev.progress


__all__ = [
    "InvariantViolation",
    "assert_valid_schedule",
    "assert_fault_invariants",
    "service_floors",
    "shard_floors",
]
