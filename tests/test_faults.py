"""Fault-tolerant serving: runtime feedback (completions / failures /
straggler detection), retry with backoff + demotion, device loss and
recovery, and the deterministic fault-injection harness.

The load-bearing contract is differential: with the injector disabled
(``FaultSpec()`` — all rates zero) every plan the service produces is
bit-identical to a run with no feedback at all; with faults enabled,
``assert_valid_schedule`` + ``assert_fault_invariants`` must hold on the
final books, and the closed loop must beat the open-loop (no-feedback)
executor on straggler streams.
"""

import pytest

from invariants import (
    assert_fault_invariants,
    assert_valid_schedule,
    service_floors,
)
from repro.core import (
    A30,
    A100,
    FaultInjector,
    FaultSpec,
    Profile,
    ProfileCoverageError,
    RetryPolicy,
    SchedulerConfig,
    SchedulingService,
    Task,
    cluster,
    demote_shrink,
    execute_open_loop,
    partition_batch,
    run_with_faults,
)
from repro.core.synth import generate_tasks, workload


def _tasks(n, seed=0, spec=A100, id_offset=0):
    return generate_tasks(
        n, spec, workload("mixed", "wide", spec), seed=seed,
        id_offset=id_offset,
    )


def _cfg(**kw):
    base = dict(max_wait_s=5.0, max_batch=8, min_batch=2)
    base.update(kw)
    return SchedulerConfig(**base)


def _stream(tasks, gap=1.5, slack=120.0):
    return [(i * gap, t, i * gap + slack) for i, t in enumerate(tasks)]


# --- RetryPolicy / demotion ------------------------------------------------

def test_retry_backoff_is_capped_exponential():
    rp = RetryPolicy(max_attempts=5, backoff_base=0.5, backoff_cap=3.0)
    assert rp.backoff(1) == 0.5
    assert rp.backoff(2) == 1.0
    assert rp.backoff(3) == 2.0
    assert rp.backoff(4) == 3.0       # capped
    assert rp.backoff(5) == 3.0
    with pytest.raises(ValueError, match="1-based"):
        rp.backoff(0)


def test_retry_policy_validation():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match=">= 0"):
        RetryPolicy(backoff_base=-1.0)


def test_demote_shrink_drops_largest_size_per_kind():
    t = Task(id=1, times={1: 10.0, 2: 6.0, 4: 4.0})
    d = demote_shrink(t, 2)
    assert set(d.times) == {1, 2}
    assert d.id == t.id
    # Profile variant: each kind loses its largest size independently
    p = Task(id=2, times=Profile({"a100": {1: 9.0, 2: 5.0},
                                  "a30": {1: 7.0}}))
    dp = demote_shrink(p, 2)
    assert set(dp.times.for_kind("a100")) == {1}
    assert set(dp.times.for_kind("a30")) == {1}
    # nothing left to shrink -> None (policy keeps the previous task)
    assert demote_shrink(Task(id=3, times={1: 5.0}), 2) is None
    rp = RetryPolicy(demote=demote_shrink)
    t1 = Task(id=4, times={1: 5.0})
    assert rp.task_for_attempt(t1, 2) is t1
    assert RetryPolicy().task_for_attempt(t, 2) is t


# --- FaultInjector determinism --------------------------------------------

def test_injector_draws_are_pure_functions_of_the_key():
    spec = FaultSpec(seed=7, noise_sigma=0.2, straggler_prob=0.3,
                     task_fail_rate=0.05)
    a, b = FaultInjector(spec), FaultInjector(spec)
    # same key -> same draw, across instances and across call order
    d1 = a.draw_execution(3, 1, 10.0)
    _ = a.draw_execution(99, 1, 10.0)
    d2 = a.draw_execution(3, 1, 10.0)
    d3 = b.draw_execution(3, 1, 10.0)
    assert d1 == d2 == d3
    # different attempt -> an independent fate
    d4 = a.draw_execution(3, 2, 10.0)
    assert d4 != d1
    # different seed -> different draws
    c = FaultInjector(FaultSpec(seed=8, noise_sigma=0.2,
                                straggler_prob=0.3, task_fail_rate=0.05))
    assert c.draw_execution(3, 1, 10.0) != d1


def test_disabled_injector_is_a_perfect_machine():
    inj = FaultInjector()
    assert not inj.enabled
    d = inj.draw_execution(5, 1, 12.5)
    assert d.duration == 12.5 and not d.fails
    assert inj.device_outages(0, 1e6) == []


def test_device_outages_windows_are_bounded_and_disjoint():
    inj = FaultInjector(FaultSpec(seed=3, device_mtbf_s=50.0,
                                  device_repair_s=10.0,
                                  max_device_losses=2))
    wins = inj.device_outages(0, 1e4)
    assert 1 <= len(wins) <= 2
    for lost, rec in wins:
        assert rec == pytest.approx(lost + 10.0)
    for (_, r1), (l2, _) in zip(wins, wins[1:]):
        assert l2 >= r1
    assert inj.device_outages(0, 1e4) == wins          # reproducible
    assert inj.device_outages(1, 1e4) != wins          # per-device stream
    assert FaultInjector(FaultSpec(seed=3)).device_outages(0, 1e4) == []


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="straggler_factor"):
        FaultSpec(straggler_factor=1.0)
    with pytest.raises(ValueError, match="noise_sigma"):
        FaultSpec(noise_sigma=-0.1)


# --- submit validation & typed coverage errors -----------------------------

def test_submit_rejects_empty_profile():
    svc = SchedulingService(A100, config=_cfg())
    with pytest.raises(ValueError, match="empty profile"):
        svc.submit(Task(id=1, times={}), arrival=0.0)


def test_submit_rejects_non_positive_durations():
    svc = SchedulingService(A100, config=_cfg())
    with pytest.raises(ValueError, match="strictly positive"):
        svc.submit(Task(id=1, times={1: 5.0, 2: 0.0}), arrival=0.0)
    with pytest.raises(ValueError, match="strictly positive"):
        svc.submit(Task(id=2, times=Profile({"a100": {1: -3.0}})),
                   arrival=0.0)


def test_submit_rejects_deadline_before_arrival():
    svc = SchedulingService(A100, config=_cfg())
    t = _tasks(1)[0]
    with pytest.raises(ValueError, match="precedes its arrival"):
        svc.submit(t, arrival=10.0, deadline=9.0)


def test_partition_batch_coverage_error_names_task_and_instance_type():
    cs = cluster(A30, A100)
    bad = Task(id=77, times=Profile({"h100": {1: 5.0}}))
    with pytest.raises(ProfileCoverageError) as ei:
        partition_batch([bad], cs)
    err = ei.value
    assert err.task_id == 77
    assert "77" in str(err) and "fits no device" in str(err)
    # dual inheritance: legacy guards on either base keep working
    assert isinstance(err, KeyError) and isinstance(err, ValueError)


def test_times_for_raises_typed_coverage_error():
    t = Task(id=5, times=Profile({"a100": {1: 5.0}}))
    with pytest.raises(ProfileCoverageError, match="task 5"):
        t.times_for("h100")


# --- report(): completions, corrections, failures --------------------------

def _committed_service(n=6, seed=0, **cfg_kw):
    tasks = _tasks(n, seed=seed)
    svc = SchedulingService(A100, config=_cfg(**cfg_kw))
    for i, t in enumerate(tasks):
        svc.submit(t, arrival=float(i) * 0.1)
    svc.flush()
    return svc, tasks


def test_report_validates_event_id_and_time():
    svc, tasks = _committed_service()
    with pytest.raises(ValueError, match="unknown runtime event"):
        svc.report(tasks[0].id, "exploded", t=svc.now)
    with pytest.raises(ValueError, match="no live committed placement"):
        svc.report(10 ** 9, "completed", t=svc.now)
    it = min(svc.committed_items(), key=lambda it: it.begin)
    svc.report(it.task.id, "completed", t=it.end, end=it.end)
    with pytest.raises(ValueError, match="non-decreasing"):
        svc.report(tasks[1].id, "completed", t=it.end - 10.0)
    with pytest.raises(ValueError, match="already reported"):
        svc.report(it.task.id, "completed", t=svc.now)


def test_on_time_completion_is_a_noop_correction():
    svc, _ = _committed_service()
    it = min(svc.committed_items(), key=lambda it: it.begin)
    svc.report(it.task.id, "completed", t=it.end, end=it.end)
    assert svc.stats.completed == 1
    assert svc.stats.corrections == []
    assert svc.completions[it.task.id] == it.end


def test_early_completion_records_a_shrink():
    svc, tasks = _committed_service()
    it = min(svc.committed_items(), key=lambda it: it.begin)
    actual = it.begin + 0.5 * it.planned_duration
    svc.report(it.task.id, "completed", t=actual)
    [ev] = svc.stats.corrections
    assert ev.kind == "shrink" and ev.task_id == it.task.id
    assert ev.new_end == actual and ev.old_end == pytest.approx(
        it.begin + it.planned_duration)
    cur = svc.mb.find_item(it.task.id)
    assert cur.corrected and cur.end == actual
    combined = svc.drain()
    assert_valid_schedule(combined, A100, tasks=tasks,
                          floors=service_floors(svc))


def test_late_completion_stretch_forces_replan_and_stays_valid():
    svc, tasks = _committed_service(replan=True)
    it = min(svc.committed_items(), key=lambda it: it.begin)
    successors = [o for o in svc.committed_items()
                  if o.begin > it.begin + 1e-9]
    actual = it.begin + 4.0 * it.planned_duration
    svc.report(it.task.id, "completed", t=actual)
    [ev] = svc.stats.corrections
    assert ev.kind == "stretch"
    # everything not yet started was pulled back and re-planned after
    # the corrected end; no successor was left planned against stale books
    fault_decisions = [d for d in svc.stats.decisions if d.route == "fault"]
    assert {d.task_id for d in fault_decisions} == set(ev.withdrawn)
    assert successors, "test stream must have successors to re-plan"
    combined = svc.drain()
    assert_valid_schedule(combined, A100, tasks=tasks,
                          floors=service_floors(svc))
    for tid in ev.withdrawn:
        cur = next(i for i in combined.items
                   if i.task.id == tid and not i.failed)
        assert cur.begin >= actual - 1e-9


def test_failure_retries_with_backoff_then_fails_permanently():
    rp = RetryPolicy(max_attempts=2, backoff_base=1.0)
    svc, tasks = _committed_service(retry=rp)
    it = min(svc.committed_items(), key=lambda it: it.begin)
    t_fail = it.begin + 0.25 * it.planned_duration
    svc.report(it.task.id, "failed", t=max(svc.now, t_fail))
    [rev] = svc.stats.retries
    assert rev.task_id == it.task.id and rev.attempt == 2
    assert rev.release == pytest.approx(rev.failed_at + 1.0)
    # drain releases the retry; its placement respects the backoff floor
    svc.drain()
    again = svc.mb.find_item(it.task.id)
    assert again is not None and not again.failed
    assert again.begin >= rev.release - 1e-9
    assert_fault_invariants(svc)
    # the truncated first attempt stays in the books as occupancy
    failed_records = [i for seg in svc.mb.segments for i in seg.items
                      if i.task.id == it.task.id and i.failed]
    assert len(failed_records) == 1
    # second failure is permanent (max_attempts=2)
    svc.report(it.task.id, "failed", t=max(svc.now, again.begin + 0.1))
    assert svc.stats.failed == [it.task.id]
    rep = svc.deadline_report()
    assert rep["failed"] == [it.task.id]


def test_failure_without_retry_policy_is_permanent():
    svc, _ = _committed_service()
    it = min(svc.committed_items(), key=lambda it: it.begin)
    svc.report(it.task.id, "failed", t=max(svc.now, it.begin + 0.1))
    assert svc.stats.failed == [it.task.id]
    assert svc.stats.retries == []


def test_straggler_is_detected_implicitly_on_poll():
    svc, tasks = _committed_service(straggler_factor=2.0)
    it = min(svc.committed_items(), key=lambda it: it.begin)
    svc.poll(it.begin + 2.5 * it.planned_duration)
    assert svc.stats.stragglers >= 1
    ev = next(e for e in svc.stats.corrections if e.kind == "straggler")
    assert ev.task_id == it.task.id
    cur = svc.mb.find_item(it.task.id)
    assert cur.corrected and cur.end > it.end
    combined = svc.drain()
    assert_valid_schedule(combined, A100, tasks=tasks,
                          floors=service_floors(svc))


# --- device loss / recovery ------------------------------------------------

def _cluster_service(n=10, seed=3, **cfg_kw):
    cs = cluster(A100, A30)
    tasks = _tasks(n, seed=seed)
    svc = SchedulingService(pool=cs, config=_cfg(**cfg_kw))
    for i, t in enumerate(tasks):
        svc.submit(t, arrival=float(i) * 0.2)
    svc.flush()
    return svc, tasks


def test_quarantine_requires_a_pool():
    svc, _ = _committed_service()
    with pytest.raises(ValueError, match="pool"):
        svc.quarantine(0, svc.now)


def test_quarantine_fails_running_withdraws_rest_and_recovers():
    rp = RetryPolicy(max_attempts=3, backoff_base=0.5)
    svc, tasks = _cluster_service(retry=rp)
    t_loss = svc.now + 1.0
    running = svc.quarantine(1, t_loss)
    [ev] = svc.stats.outages
    assert ev.device == 1 and ev.lost_at == t_loss
    assert set(ev.died_running) == set(running)
    # running attempts died with the device -> retry path
    assert {r.task_id for r in svc.stats.retries} == set(running)
    # withdrawn placements were re-planned immediately (nothing parked
    # here: both kinds in this workload run on the surviving A100)
    for tid in ev.withdrawn:
        assert svc.mb.find_item(tid) is not None
    # admission floors see only surviving capacity until recovery: a
    # probe only the lost A30 can run has no completion bound at all
    probe = Task(id=9999, times=Profile({"A30": {1: 3.0, 2: 2.0, 4: 1.5}}))
    lb_degraded = svc.completion_lower_bound(probe, svc.now)
    assert lb_degraded == float("inf")
    svc.recover(1, t_loss + 30.0)
    assert svc.stats.outages[0].recovered_at == t_loss + 30.0
    lb_recovered = svc.completion_lower_bound(probe, svc.now)
    assert lb_recovered < float("inf")
    svc.drain()
    assert_fault_invariants(svc)


def test_quarantine_accepts_device_spec_or_index():
    svc, _ = _cluster_service()
    t_loss = svc.now + 1.0
    # the DeviceSpec itself resolves to its pool index
    svc.quarantine(svc.cluster.devices[1], t_loss)
    assert svc.stats.outages[-1].device == 1
    svc.recover(svc.cluster.devices[1], t_loss + 5.0)
    assert svc.stats.outages[-1].recovered_at == t_loss + 5.0
    # a spec that is not in the pool names itself and the pool members
    with pytest.raises(ValueError, match="not in this pool"):
        svc.quarantine(A100.degrade([]), svc.now)
    svc.drain()
    assert_fault_invariants(svc)


def test_quarantine_never_strands_withdrawn_tasks():
    svc, tasks = _cluster_service(n=12, seed=11,
                                  retry=RetryPolicy(max_attempts=2))
    svc.quarantine(0, svc.now + 0.5)
    svc.drain()
    assert_fault_invariants(svc)   # includes the no-stranding check
    live = {it.task.id for it in svc.committed_items()}
    for tid in svc.stats.outages[0].withdrawn:
        assert (tid in live or tid in svc.stats.failed
                or tid in svc.stats.rejected)


def test_unsupported_tasks_park_through_outage_and_return_on_recovery():
    # two-kind pool; tasks that only run on the A30 must park while it
    # is quarantined and be re-admitted (not dropped) on recovery
    cs = cluster(A100, A30)
    a30_only = [
        Task(id=900 + i, times=Profile({"A30": {1: 3.0, 2: 2.0, 4: 1.5}}))
        for i in range(2)
    ]
    svc = SchedulingService(pool=cs, config=_cfg(max_batch=2))
    for i, t in enumerate(a30_only):
        svc.submit(t, arrival=float(i))
    svc.flush()
    assert len(svc.committed_items()) == 2
    svc.quarantine(1, svc.now + 0.1)
    [ev] = svc.stats.outages
    assert set(ev.parked) == set(ev.withdrawn) != set()
    assert all(svc.mb.find_item(tid) is None for tid in ev.parked)
    svc.recover(1, svc.now + 20.0)
    for tid in ev.parked:
        it = svc.mb.find_item(tid)
        assert it is not None and it.begin >= ev.lost_at - 1e-9
    svc.drain()
    assert_fault_invariants(svc)
    assert svc.stats.rejected == []


def test_parked_tasks_rejected_at_drain_if_never_recovered():
    cs = cluster(A100, A30)
    only_a30 = Task(id=950, times=Profile({"A30": {1: 3.0, 2: 2.0, 4: 1.5}}))
    svc = SchedulingService(pool=cs, config=_cfg(max_batch=1))
    svc.submit(only_a30, arrival=0.0, deadline=100.0)
    svc.flush()
    svc.quarantine(1, svc.now + 0.1)
    svc.drain()
    assert svc.stats.rejected == [950]
    assert svc.deadline_report()["missed"] == []   # rejected, not missed
    assert_fault_invariants(svc)


# --- withdraw_uncommitted boundary semantics (re-plan correctness) ---------

def test_withdraw_keeps_placement_beginning_exactly_at_t():
    svc, _ = _committed_service()
    it = min(svc.committed_items(), key=lambda it: it.begin)
    mb = svc.mb.clone()
    wd = mb.withdraw_uncommitted(it.begin)
    assert it.task.id not in {t.id for t in wd}   # begin == t: started
    assert mb.find_item(it.task.id) is not None


def test_withdraw_inside_reconfig_window_keeps_the_reconfig():
    svc, tasks = _committed_service(n=8, seed=4)
    reconfigs = [rc for seg in svc.mb.segments for rc in seg.reconfigs]
    if not reconfigs:
        pytest.skip("plan has no reconfiguration to probe")
    rc = min(reconfigs, key=lambda r: r.begin)
    t_mid = 0.5 * (rc.begin + rc.end)
    mb = svc.mb.clone()
    mb.withdraw_uncommitted(t_mid)
    kept = [r for seg in mb.segments for r in seg.reconfigs]
    assert any(abs(r.begin - rc.begin) < 1e-9 for r in kept), \
        "an in-progress reconfiguration must survive withdrawal"


def test_withdraw_on_single_device_cluster_tail():
    cs = cluster(A100)
    tasks = _tasks(5, seed=6)
    svc = SchedulingService(pool=cs, config=_cfg(max_batch=5))
    for t in tasks:
        svc.submit(t, arrival=0.0)
    m0 = svc.mb.makespan
    # beyond the makespan nothing is uncommitted; tail must be untouched
    mb = svc.mb.clone()
    assert mb.withdraw_uncommitted(m0 + 1.0) == []
    assert mb.makespan == m0
    # at time zero everything comes back and the tail resets
    mb2 = svc.mb.clone()
    wd = mb2.withdraw_uncommitted(0.0)
    assert {t.id for t in wd} == {t.id for t in tasks}
    assert mb2.makespan == 0.0


# --- differential: disabled injector == pre-feedback behaviour -------------

def _plan_signature(svc):
    return sorted(
        (it.task.id, it.node.key, it.begin, it.end, it.size)
        for it in svc.combined_schedule().items
    )


@pytest.mark.parametrize("replan", [False, True])
def test_disabled_injector_plans_bit_identical_single_device(replan):
    tasks = _tasks(12, seed=9)
    stream = _stream(tasks)
    cfg = _cfg(replan=replan, straggler_factor=3.0,
               retry=RetryPolicy())
    ref = SchedulingService(A100, config=_cfg(replan=replan))
    for a, t, dl in stream:
        ref.submit(t, arrival=a, deadline=dl)
    ref.drain()
    svc = SchedulingService(A100, config=cfg)
    rep = run_with_faults(svc, stream, injector=FaultInjector())
    assert _plan_signature(svc) == _plan_signature(ref)
    assert rep.failed == [] and len(rep.completions) == len(tasks)
    # every completion reported exactly at its planned end
    ends = {it.task.id: it.end for it in ref.combined_schedule().items}
    assert rep.completions == ends
    assert svc.stats.corrections == [] and svc.stats.stragglers == 0


def test_disabled_injector_plans_bit_identical_cluster():
    cs = cluster(A100, A30)
    tasks = _tasks(10, seed=13)
    stream = _stream(tasks)
    ref = SchedulingService(pool=cluster(A100, A30), config=_cfg())
    for a, t, dl in stream:
        ref.submit(t, arrival=a, deadline=dl)
    ref.drain()
    svc = SchedulingService(pool=cs, config=_cfg(straggler_factor=3.0))
    run_with_faults(svc, stream, injector=FaultInjector())
    assert _plan_signature(svc) == _plan_signature(ref)


# --- closed loop under faults: invariants + it beats open loop -------------

FAULTY = FaultSpec(seed=2, noise_sigma=0.08, straggler_prob=0.2,
                   task_fail_rate=0.008, straggler_factor=3.0)


def test_closed_loop_under_faults_keeps_all_invariants():
    tasks = _tasks(14, seed=21)
    stream = _stream(tasks)
    svc = SchedulingService(A100, config=_cfg(
        straggler_factor=2.5, retry=RetryPolicy(max_attempts=3)))
    rep = run_with_faults(svc, stream, injector=FaultInjector(FAULTY))
    assert_fault_invariants(svc)
    combined = svc.combined_schedule()
    done = set(rep.completions) | set(rep.failed)
    assert done == {t.id for t in tasks}, "every task must be resolved"
    assert_valid_schedule(combined, A100, floors=service_floors(svc))


def test_closed_loop_with_outages_keeps_all_invariants():
    cs = cluster(A100, A30)
    tasks = _tasks(16, seed=22)
    stream = _stream(tasks)
    spec = FaultSpec(seed=5, noise_sigma=0.05, straggler_prob=0.1,
                     task_fail_rate=0.005, device_mtbf_s=60.0,
                     device_repair_s=20.0)
    svc = SchedulingService(pool=cs, config=_cfg(
        straggler_factor=2.5, retry=RetryPolicy(max_attempts=3)))
    rep = run_with_faults(svc, stream, injector=FaultInjector(spec))
    assert svc.stats.outages, "seeded MTBF must produce an outage"
    assert_fault_invariants(svc)
    resolved = (set(rep.completions) | set(rep.failed)
                | set(svc.stats.rejected))
    assert resolved == {t.id for t in tasks}


def test_closed_loop_beats_open_loop_on_straggler_streams():
    tasks = _tasks(16, seed=31)
    deadlines = {}
    stream = []
    for i, t in enumerate(tasks):
        arrival = i * 1.0
        dl = arrival + 150.0
        deadlines[t.id] = dl
        stream.append((arrival, t, dl))
    spec = FaultSpec(seed=4, straggler_prob=0.25, straggler_factor=4.0)
    # open loop: the frozen no-feedback plan under the same draws
    ref = SchedulingService(A100, config=_cfg())
    for a, t, dl in stream:
        ref.submit(t, arrival=a, deadline=dl)
    open_rep = execute_open_loop(ref.drain(), FaultInjector(spec))
    # closed loop: straggler detection + forced re-planning
    svc = SchedulingService(A100, config=_cfg(
        replan=True, straggler_factor=2.0))
    closed_rep = run_with_faults(svc, stream, injector=FaultInjector(spec))
    assert svc.stats.stragglers > 0, "stream must actually straggle"
    assert closed_rep.miss_rate(deadlines) < open_rep.miss_rate(deadlines)


def test_harness_run_is_reproducible():
    cs = cluster(A100, A30)
    tasks = _tasks(12, seed=40)
    stream = _stream(tasks)
    spec = FaultSpec(seed=9, noise_sigma=0.1, straggler_prob=0.15,
                     task_fail_rate=0.01, device_mtbf_s=80.0,
                     device_repair_s=25.0)
    cfg = _cfg(straggler_factor=2.5, retry=RetryPolicy(max_attempts=3))
    reps = []
    for _ in range(2):
        svc = SchedulingService(pool=cluster(A100, A30), config=cfg)
        reps.append(run_with_faults(svc, stream,
                                    injector=FaultInjector(spec)))
    assert reps[0].completions == reps[1].completions
    assert reps[0].failed == reps[1].failed
    assert reps[0].recovery_latency == reps[1].recovery_latency
