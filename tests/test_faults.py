"""Fault-tolerant serving: runtime feedback (completions / failures /
straggler detection), retry with backoff + demotion, device loss and
recovery, and the deterministic fault-injection harness.

The load-bearing contract is differential: with the injector disabled
(``FaultSpec()`` — all rates zero) every plan the service produces is
bit-identical to a run with no feedback at all; with faults enabled,
``assert_valid_schedule`` + ``assert_fault_invariants`` must hold on the
final books, and the closed loop must beat the open-loop (no-feedback)
executor on straggler streams.
"""

import pytest

from invariants import (
    assert_fault_invariants,
    assert_valid_schedule,
    service_floors,
)
from repro.core import (
    A30,
    A100,
    FaultInjector,
    FaultSpec,
    Profile,
    ProfileCalibration,
    ProfileCoverageError,
    RetryPolicy,
    SchedulerConfig,
    SchedulingService,
    SpeculationPolicy,
    Task,
    cluster,
    demote_shrink,
    execute_open_loop,
    partition_batch,
    remainder_task,
    run_with_faults,
    transfer_profile,
)
from repro.core.synth import generate_tasks, workload


def _tasks(n, seed=0, spec=A100, id_offset=0):
    return generate_tasks(
        n, spec, workload("mixed", "wide", spec), seed=seed,
        id_offset=id_offset,
    )


def _cfg(**kw):
    base = dict(max_wait_s=5.0, max_batch=8, min_batch=2)
    base.update(kw)
    return SchedulerConfig(**base)


def _stream(tasks, gap=1.5, slack=120.0):
    return [(i * gap, t, i * gap + slack) for i, t in enumerate(tasks)]


# --- RetryPolicy / demotion ------------------------------------------------

def test_retry_backoff_is_capped_exponential():
    rp = RetryPolicy(max_attempts=5, backoff_base=0.5, backoff_cap=3.0)
    assert rp.backoff(1) == 0.5
    assert rp.backoff(2) == 1.0
    assert rp.backoff(3) == 2.0
    assert rp.backoff(4) == 3.0       # capped
    assert rp.backoff(5) == 3.0
    with pytest.raises(ValueError, match="1-based"):
        rp.backoff(0)


def test_retry_policy_validation():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match=">= 0"):
        RetryPolicy(backoff_base=-1.0)


def test_demote_shrink_drops_largest_size_per_kind():
    t = Task(id=1, times={1: 10.0, 2: 6.0, 4: 4.0})
    d = demote_shrink(t, 2)
    assert set(d.times) == {1, 2}
    assert d.id == t.id
    # Profile variant: each kind loses its largest size independently
    p = Task(id=2, times=Profile({"a100": {1: 9.0, 2: 5.0},
                                  "a30": {1: 7.0}}))
    dp = demote_shrink(p, 2)
    assert set(dp.times.for_kind("a100")) == {1}
    assert set(dp.times.for_kind("a30")) == {1}
    # nothing left to shrink -> None (policy keeps the previous task)
    assert demote_shrink(Task(id=3, times={1: 5.0}), 2) is None
    rp = RetryPolicy(demote=demote_shrink)
    t1 = Task(id=4, times={1: 5.0})
    assert rp.task_for_attempt(t1, 2) is t1
    assert RetryPolicy().task_for_attempt(t, 2) is t


# --- FaultInjector determinism --------------------------------------------

def test_injector_draws_are_pure_functions_of_the_key():
    spec = FaultSpec(seed=7, noise_sigma=0.2, straggler_prob=0.3,
                     task_fail_rate=0.05)
    a, b = FaultInjector(spec), FaultInjector(spec)
    # same key -> same draw, across instances and across call order
    d1 = a.draw_execution(3, 1, 10.0)
    _ = a.draw_execution(99, 1, 10.0)
    d2 = a.draw_execution(3, 1, 10.0)
    d3 = b.draw_execution(3, 1, 10.0)
    assert d1 == d2 == d3
    # different attempt -> an independent fate
    d4 = a.draw_execution(3, 2, 10.0)
    assert d4 != d1
    # different seed -> different draws
    c = FaultInjector(FaultSpec(seed=8, noise_sigma=0.2,
                                straggler_prob=0.3, task_fail_rate=0.05))
    assert c.draw_execution(3, 1, 10.0) != d1


def test_disabled_injector_is_a_perfect_machine():
    inj = FaultInjector()
    assert not inj.enabled
    d = inj.draw_execution(5, 1, 12.5)
    assert d.duration == 12.5 and not d.fails
    assert inj.device_outages(0, 1e6) == []


def test_device_outages_windows_are_bounded_and_disjoint():
    inj = FaultInjector(FaultSpec(seed=3, device_mtbf_s=50.0,
                                  device_repair_s=10.0,
                                  max_device_losses=2))
    wins = inj.device_outages(0, 1e4)
    assert 1 <= len(wins) <= 2
    for lost, rec in wins:
        assert rec == pytest.approx(lost + 10.0)
    for (_, r1), (l2, _) in zip(wins, wins[1:]):
        assert l2 >= r1
    assert inj.device_outages(0, 1e4) == wins          # reproducible
    assert inj.device_outages(1, 1e4) != wins          # per-device stream
    assert FaultInjector(FaultSpec(seed=3)).device_outages(0, 1e4) == []


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="straggler_factor"):
        FaultSpec(straggler_factor=1.0)
    with pytest.raises(ValueError, match="noise_sigma"):
        FaultSpec(noise_sigma=-0.1)


# --- submit validation & typed coverage errors -----------------------------

def test_submit_rejects_empty_profile():
    svc = SchedulingService(A100, config=_cfg())
    with pytest.raises(ValueError, match="empty profile"):
        svc.submit(Task(id=1, times={}), arrival=0.0)


def test_submit_rejects_non_positive_durations():
    svc = SchedulingService(A100, config=_cfg())
    with pytest.raises(ValueError, match="strictly positive"):
        svc.submit(Task(id=1, times={1: 5.0, 2: 0.0}), arrival=0.0)
    with pytest.raises(ValueError, match="strictly positive"):
        svc.submit(Task(id=2, times=Profile({"a100": {1: -3.0}})),
                   arrival=0.0)


def test_submit_rejects_deadline_before_arrival():
    svc = SchedulingService(A100, config=_cfg())
    t = _tasks(1)[0]
    with pytest.raises(ValueError, match="precedes its arrival"):
        svc.submit(t, arrival=10.0, deadline=9.0)


def test_partition_batch_coverage_error_names_task_and_instance_type():
    cs = cluster(A30, A100)
    bad = Task(id=77, times=Profile({"h100": {1: 5.0}}))
    with pytest.raises(ProfileCoverageError) as ei:
        partition_batch([bad], cs)
    err = ei.value
    assert err.task_id == 77
    assert "77" in str(err) and "fits no device" in str(err)
    # dual inheritance: legacy guards on either base keep working
    assert isinstance(err, KeyError) and isinstance(err, ValueError)


def test_times_for_raises_typed_coverage_error():
    t = Task(id=5, times=Profile({"a100": {1: 5.0}}))
    with pytest.raises(ProfileCoverageError, match="task 5"):
        t.times_for("h100")


# --- report(): completions, corrections, failures --------------------------

def _committed_service(n=6, seed=0, **cfg_kw):
    tasks = _tasks(n, seed=seed)
    svc = SchedulingService(A100, config=_cfg(**cfg_kw))
    for i, t in enumerate(tasks):
        svc.submit(t, arrival=float(i) * 0.1)
    svc.flush()
    return svc, tasks


def test_report_validates_event_id_and_time():
    svc, tasks = _committed_service()
    with pytest.raises(ValueError, match="unknown runtime event"):
        svc.report(tasks[0].id, "exploded", t=svc.now)
    with pytest.raises(ValueError, match="no live committed placement"):
        svc.report(10 ** 9, "completed", t=svc.now)
    it = min(svc.committed_items(), key=lambda it: it.begin)
    svc.report(it.task.id, "completed", t=it.end, end=it.end)
    with pytest.raises(ValueError, match="non-decreasing"):
        svc.report(tasks[1].id, "completed", t=it.end - 10.0)
    with pytest.raises(ValueError, match="already reported"):
        svc.report(it.task.id, "completed", t=svc.now)


def test_on_time_completion_is_a_noop_correction():
    svc, _ = _committed_service()
    it = min(svc.committed_items(), key=lambda it: it.begin)
    svc.report(it.task.id, "completed", t=it.end, end=it.end)
    assert svc.stats.completed == 1
    assert svc.stats.corrections == []
    assert svc.completions[it.task.id] == it.end


def test_early_completion_records_a_shrink():
    svc, tasks = _committed_service()
    it = min(svc.committed_items(), key=lambda it: it.begin)
    actual = it.begin + 0.5 * it.planned_duration
    svc.report(it.task.id, "completed", t=actual)
    [ev] = svc.stats.corrections
    assert ev.kind == "shrink" and ev.task_id == it.task.id
    assert ev.new_end == actual and ev.old_end == pytest.approx(
        it.begin + it.planned_duration)
    cur = svc.mb.find_item(it.task.id)
    assert cur.corrected and cur.end == actual
    combined = svc.drain()
    assert_valid_schedule(combined, A100, tasks=tasks,
                          floors=service_floors(svc))


def test_late_completion_stretch_forces_replan_and_stays_valid():
    svc, tasks = _committed_service(replan=True)
    it = min(svc.committed_items(), key=lambda it: it.begin)
    successors = [o for o in svc.committed_items()
                  if o.begin > it.begin + 1e-9]
    actual = it.begin + 4.0 * it.planned_duration
    svc.report(it.task.id, "completed", t=actual)
    [ev] = svc.stats.corrections
    assert ev.kind == "stretch"
    # everything not yet started was pulled back and re-planned after
    # the corrected end; no successor was left planned against stale books
    fault_decisions = [d for d in svc.stats.decisions if d.route == "fault"]
    assert {d.task_id for d in fault_decisions} == set(ev.withdrawn)
    assert successors, "test stream must have successors to re-plan"
    combined = svc.drain()
    assert_valid_schedule(combined, A100, tasks=tasks,
                          floors=service_floors(svc))
    for tid in ev.withdrawn:
        cur = next(i for i in combined.items
                   if i.task.id == tid and not i.failed)
        assert cur.begin >= actual - 1e-9


def test_failure_retries_with_backoff_then_fails_permanently():
    rp = RetryPolicy(max_attempts=2, backoff_base=1.0)
    svc, tasks = _committed_service(retry=rp)
    it = min(svc.committed_items(), key=lambda it: it.begin)
    t_fail = it.begin + 0.25 * it.planned_duration
    svc.report(it.task.id, "failed", t=max(svc.now, t_fail))
    [rev] = svc.stats.retries
    assert rev.task_id == it.task.id and rev.attempt == 2
    assert rev.release == pytest.approx(rev.failed_at + 1.0)
    # drain releases the retry; its placement respects the backoff floor
    svc.drain()
    again = svc.mb.find_item(it.task.id)
    assert again is not None and not again.failed
    assert again.begin >= rev.release - 1e-9
    assert_fault_invariants(svc)
    # the truncated first attempt stays in the books as occupancy
    failed_records = [i for seg in svc.mb.segments for i in seg.items
                      if i.task.id == it.task.id and i.failed]
    assert len(failed_records) == 1
    # second failure is permanent (max_attempts=2)
    svc.report(it.task.id, "failed", t=max(svc.now, again.begin + 0.1))
    assert svc.stats.failed == [it.task.id]
    rep = svc.deadline_report()
    assert rep["failed"] == [it.task.id]


def test_failure_without_retry_policy_is_permanent():
    svc, _ = _committed_service()
    it = min(svc.committed_items(), key=lambda it: it.begin)
    svc.report(it.task.id, "failed", t=max(svc.now, it.begin + 0.1))
    assert svc.stats.failed == [it.task.id]
    assert svc.stats.retries == []


def test_straggler_is_detected_implicitly_on_poll():
    svc, tasks = _committed_service(straggler_factor=2.0)
    it = min(svc.committed_items(), key=lambda it: it.begin)
    svc.poll(it.begin + 2.5 * it.planned_duration)
    assert svc.stats.stragglers >= 1
    ev = next(e for e in svc.stats.corrections if e.kind == "straggler")
    assert ev.task_id == it.task.id
    cur = svc.mb.find_item(it.task.id)
    assert cur.corrected and cur.end > it.end
    combined = svc.drain()
    assert_valid_schedule(combined, A100, tasks=tasks,
                          floors=service_floors(svc))


# --- device loss / recovery ------------------------------------------------

def _cluster_service(n=10, seed=3, **cfg_kw):
    cs = cluster(A100, A30)
    tasks = _tasks(n, seed=seed)
    svc = SchedulingService(pool=cs, config=_cfg(**cfg_kw))
    for i, t in enumerate(tasks):
        svc.submit(t, arrival=float(i) * 0.2)
    svc.flush()
    return svc, tasks


def test_quarantine_requires_a_pool():
    svc, _ = _committed_service()
    with pytest.raises(ValueError, match="pool"):
        svc.quarantine(0, svc.now)


def test_quarantine_fails_running_withdraws_rest_and_recovers():
    rp = RetryPolicy(max_attempts=3, backoff_base=0.5)
    svc, tasks = _cluster_service(retry=rp)
    t_loss = svc.now + 1.0
    running = svc.quarantine(1, t_loss)
    [ev] = svc.stats.outages
    assert ev.device == 1 and ev.lost_at == t_loss
    assert set(ev.died_running) == set(running)
    # running attempts died with the device -> retry path
    assert {r.task_id for r in svc.stats.retries} == set(running)
    # withdrawn placements were re-planned immediately (nothing parked
    # here: both kinds in this workload run on the surviving A100)
    for tid in ev.withdrawn:
        assert svc.mb.find_item(tid) is not None
    # admission floors see only surviving capacity until recovery: a
    # probe only the lost A30 can run has no completion bound at all
    probe = Task(id=9999, times=Profile({"A30": {1: 3.0, 2: 2.0, 4: 1.5}}))
    lb_degraded = svc.completion_lower_bound(probe, svc.now)
    assert lb_degraded == float("inf")
    svc.recover(1, t_loss + 30.0)
    assert svc.stats.outages[0].recovered_at == t_loss + 30.0
    lb_recovered = svc.completion_lower_bound(probe, svc.now)
    assert lb_recovered < float("inf")
    svc.drain()
    assert_fault_invariants(svc)


def test_quarantine_accepts_device_spec_or_index():
    svc, _ = _cluster_service()
    t_loss = svc.now + 1.0
    # the DeviceSpec itself resolves to its pool index
    svc.quarantine(svc.cluster.devices[1], t_loss)
    assert svc.stats.outages[-1].device == 1
    svc.recover(svc.cluster.devices[1], t_loss + 5.0)
    assert svc.stats.outages[-1].recovered_at == t_loss + 5.0
    # a spec that is not in the pool names itself and the pool members
    with pytest.raises(ValueError, match="not in this pool"):
        svc.quarantine(A100.degrade([]), svc.now)
    svc.drain()
    assert_fault_invariants(svc)


def test_quarantine_never_strands_withdrawn_tasks():
    svc, tasks = _cluster_service(n=12, seed=11,
                                  retry=RetryPolicy(max_attempts=2))
    svc.quarantine(0, svc.now + 0.5)
    svc.drain()
    assert_fault_invariants(svc)   # includes the no-stranding check
    live = {it.task.id for it in svc.committed_items()}
    for tid in svc.stats.outages[0].withdrawn:
        assert (tid in live or tid in svc.stats.failed
                or tid in svc.stats.rejected)


def test_unsupported_tasks_park_through_outage_and_return_on_recovery():
    # two-kind pool; tasks that only run on the A30 must park while it
    # is quarantined and be re-admitted (not dropped) on recovery
    cs = cluster(A100, A30)
    a30_only = [
        Task(id=900 + i, times=Profile({"A30": {1: 3.0, 2: 2.0, 4: 1.5}}))
        for i in range(2)
    ]
    svc = SchedulingService(pool=cs, config=_cfg(max_batch=2))
    for i, t in enumerate(a30_only):
        svc.submit(t, arrival=float(i))
    svc.flush()
    assert len(svc.committed_items()) == 2
    svc.quarantine(1, svc.now + 0.1)
    [ev] = svc.stats.outages
    assert set(ev.parked) == set(ev.withdrawn) != set()
    assert all(svc.mb.find_item(tid) is None for tid in ev.parked)
    svc.recover(1, svc.now + 20.0)
    for tid in ev.parked:
        it = svc.mb.find_item(tid)
        assert it is not None and it.begin >= ev.lost_at - 1e-9
    svc.drain()
    assert_fault_invariants(svc)
    assert svc.stats.rejected == []


def test_parked_tasks_rejected_at_drain_if_never_recovered():
    cs = cluster(A100, A30)
    only_a30 = Task(id=950, times=Profile({"A30": {1: 3.0, 2: 2.0, 4: 1.5}}))
    svc = SchedulingService(pool=cs, config=_cfg(max_batch=1))
    svc.submit(only_a30, arrival=0.0, deadline=100.0)
    svc.flush()
    svc.quarantine(1, svc.now + 0.1)
    svc.drain()
    assert svc.stats.rejected == [950]
    assert svc.deadline_report()["missed"] == []   # rejected, not missed
    assert_fault_invariants(svc)


# --- withdraw_uncommitted boundary semantics (re-plan correctness) ---------

def test_withdraw_keeps_placement_beginning_exactly_at_t():
    svc, _ = _committed_service()
    it = min(svc.committed_items(), key=lambda it: it.begin)
    mb = svc.mb.clone()
    wd = mb.withdraw_uncommitted(it.begin)
    assert it.task.id not in {t.id for t in wd}   # begin == t: started
    assert mb.find_item(it.task.id) is not None


def test_withdraw_inside_reconfig_window_keeps_the_reconfig():
    svc, tasks = _committed_service(n=8, seed=4)
    reconfigs = [rc for seg in svc.mb.segments for rc in seg.reconfigs]
    if not reconfigs:
        pytest.skip("plan has no reconfiguration to probe")
    rc = min(reconfigs, key=lambda r: r.begin)
    t_mid = 0.5 * (rc.begin + rc.end)
    mb = svc.mb.clone()
    mb.withdraw_uncommitted(t_mid)
    kept = [r for seg in mb.segments for r in seg.reconfigs]
    assert any(abs(r.begin - rc.begin) < 1e-9 for r in kept), \
        "an in-progress reconfiguration must survive withdrawal"


def test_withdraw_on_single_device_cluster_tail():
    cs = cluster(A100)
    tasks = _tasks(5, seed=6)
    svc = SchedulingService(pool=cs, config=_cfg(max_batch=5))
    for t in tasks:
        svc.submit(t, arrival=0.0)
    m0 = svc.mb.makespan
    # beyond the makespan nothing is uncommitted; tail must be untouched
    mb = svc.mb.clone()
    assert mb.withdraw_uncommitted(m0 + 1.0) == []
    assert mb.makespan == m0
    # at time zero everything comes back and the tail resets
    mb2 = svc.mb.clone()
    wd = mb2.withdraw_uncommitted(0.0)
    assert {t.id for t in wd} == {t.id for t in tasks}
    assert mb2.makespan == 0.0


# --- differential: disabled injector == pre-feedback behaviour -------------

def _plan_signature(svc):
    return sorted(
        (it.task.id, it.node.key, it.begin, it.end, it.size)
        for it in svc.combined_schedule().items
    )


@pytest.mark.parametrize("replan", [False, True])
def test_disabled_injector_plans_bit_identical_single_device(replan):
    tasks = _tasks(12, seed=9)
    stream = _stream(tasks)
    cfg = _cfg(replan=replan, straggler_factor=3.0,
               retry=RetryPolicy())
    ref = SchedulingService(A100, config=_cfg(replan=replan))
    for a, t, dl in stream:
        ref.submit(t, arrival=a, deadline=dl)
    ref.drain()
    svc = SchedulingService(A100, config=cfg)
    rep = run_with_faults(svc, stream, injector=FaultInjector())
    assert _plan_signature(svc) == _plan_signature(ref)
    assert rep.failed == [] and len(rep.completions) == len(tasks)
    # every completion reported exactly at its planned end
    ends = {it.task.id: it.end for it in ref.combined_schedule().items}
    assert rep.completions == ends
    assert svc.stats.corrections == [] and svc.stats.stragglers == 0


def test_disabled_injector_plans_bit_identical_cluster():
    cs = cluster(A100, A30)
    tasks = _tasks(10, seed=13)
    stream = _stream(tasks)
    ref = SchedulingService(pool=cluster(A100, A30), config=_cfg())
    for a, t, dl in stream:
        ref.submit(t, arrival=a, deadline=dl)
    ref.drain()
    svc = SchedulingService(pool=cs, config=_cfg(straggler_factor=3.0))
    run_with_faults(svc, stream, injector=FaultInjector())
    assert _plan_signature(svc) == _plan_signature(ref)


# --- closed loop under faults: invariants + it beats open loop -------------

FAULTY = FaultSpec(seed=2, noise_sigma=0.08, straggler_prob=0.2,
                   task_fail_rate=0.008, straggler_factor=3.0)


def test_closed_loop_under_faults_keeps_all_invariants():
    tasks = _tasks(14, seed=21)
    stream = _stream(tasks)
    svc = SchedulingService(A100, config=_cfg(
        straggler_factor=2.5, retry=RetryPolicy(max_attempts=3)))
    rep = run_with_faults(svc, stream, injector=FaultInjector(FAULTY))
    assert_fault_invariants(svc)
    combined = svc.combined_schedule()
    done = set(rep.completions) | set(rep.failed)
    assert done == {t.id for t in tasks}, "every task must be resolved"
    assert_valid_schedule(combined, A100, floors=service_floors(svc))


def test_closed_loop_with_outages_keeps_all_invariants():
    cs = cluster(A100, A30)
    tasks = _tasks(16, seed=22)
    stream = _stream(tasks)
    spec = FaultSpec(seed=5, noise_sigma=0.05, straggler_prob=0.1,
                     task_fail_rate=0.005, device_mtbf_s=60.0,
                     device_repair_s=20.0)
    svc = SchedulingService(pool=cs, config=_cfg(
        straggler_factor=2.5, retry=RetryPolicy(max_attempts=3)))
    rep = run_with_faults(svc, stream, injector=FaultInjector(spec))
    assert svc.stats.outages, "seeded MTBF must produce an outage"
    assert_fault_invariants(svc)
    resolved = (set(rep.completions) | set(rep.failed)
                | set(svc.stats.rejected))
    assert resolved == {t.id for t in tasks}


def test_closed_loop_beats_open_loop_on_straggler_streams():
    tasks = _tasks(16, seed=31)
    deadlines = {}
    stream = []
    for i, t in enumerate(tasks):
        arrival = i * 1.0
        dl = arrival + 150.0
        deadlines[t.id] = dl
        stream.append((arrival, t, dl))
    spec = FaultSpec(seed=4, straggler_prob=0.25, straggler_factor=4.0)
    # open loop: the frozen no-feedback plan under the same draws
    ref = SchedulingService(A100, config=_cfg())
    for a, t, dl in stream:
        ref.submit(t, arrival=a, deadline=dl)
    open_rep = execute_open_loop(ref.drain(), FaultInjector(spec))
    # closed loop: straggler detection + forced re-planning
    svc = SchedulingService(A100, config=_cfg(
        replan=True, straggler_factor=2.0))
    closed_rep = run_with_faults(svc, stream, injector=FaultInjector(spec))
    assert svc.stats.stragglers > 0, "stream must actually straggle"
    assert closed_rep.miss_rate(deadlines) < open_rep.miss_rate(deadlines)


def test_harness_run_is_reproducible():
    cs = cluster(A100, A30)
    tasks = _tasks(12, seed=40)
    stream = _stream(tasks)
    spec = FaultSpec(seed=9, noise_sigma=0.1, straggler_prob=0.15,
                     task_fail_rate=0.01, device_mtbf_s=80.0,
                     device_repair_s=25.0)
    cfg = _cfg(straggler_factor=2.5, retry=RetryPolicy(max_attempts=3))
    reps = []
    for _ in range(2):
        svc = SchedulingService(pool=cluster(A100, A30), config=cfg)
        reps.append(run_with_faults(svc, stream,
                                    injector=FaultInjector(spec)))
    assert reps[0].completions == reps[1].completions
    assert reps[0].failed == reps[1].failed
    assert reps[0].recovery_latency == reps[1].recovery_latency


# --- straggler speculation (backup attempts) -------------------------------

def _speculating_service(n=2, seed=0, **cfg_kw):
    """A sparse A100 stream whose earliest placement, straggled past the
    3x boundary, deterministically launches a backup attempt."""
    base = dict(straggler_factor=3.0, speculation=SpeculationPolicy(),
                retry=RetryPolicy(max_attempts=3, backoff_base=0.5))
    base.update(cfg_kw)
    tasks = _tasks(n, seed=seed)
    svc = SchedulingService(A100, config=_cfg(**base))
    for i, t in enumerate(tasks):
        svc.submit(t, arrival=float(i) * 0.1)
    svc.flush()
    it = min(svc.committed_items(), key=lambda it: it.begin)
    svc.poll(it.begin + 3.5 * it.planned_duration)
    return svc, tasks, it.task.id


def _resolve_open_races(svc):
    """Reports the primary of every unresolved race as completed (at its
    current planned end), so a drained schedule covers the batch exactly.
    Later reports may straggle siblings into new races — loop to a fixed
    point."""
    while True:
        opened = [e for e in svc.stats.speculations if e.winner is None]
        if not opened:
            return
        for e in opened:
            it = svc.mb.find_item(e.task_id)
            t_done = max(svc.now, it.end)
            svc.report(e.task_id, "completed", t=t_done, end=t_done)


def test_straggler_launches_backup_with_provable_gain():
    svc, tasks, tid = _speculating_service()
    [ev] = svc.stats.speculations
    assert ev.task_id == tid and ev.winner is None
    assert ev.backup_end < ev.primary_end - 1e-9
    # both records are live and disjoint: the race is on
    it_p = svc.mb.find_item(tid)
    it_b = svc.mb.find_item(ev.backup_id)
    assert it_p is not None and it_b is not None
    assert not set(it_p.node.blocked_cells) & set(it_b.node.blocked_cells) \
        or it_b.begin >= it_p.end - 1e-9
    [d] = [d for d in svc.stats.decisions if d.route == "speculate"]
    assert d.task_id == ev.backup_id


def test_backup_wins_relabels_record_and_cancels_primary():
    svc, tasks, tid = _speculating_service()
    [ev] = svc.stats.speculations
    it_b = svc.mb.find_item(ev.backup_id)
    actual = it_b.begin + 0.9 * it_b.planned_duration
    svc.report(ev.backup_id, "completed", t=max(svc.now, actual), end=actual)
    [ev] = [e for e in svc.stats.speculations if e.task_id == tid]
    assert ev.winner == "backup" and ev.resolved_at is not None
    # exactly one live record for the logical task: the re-keyed winner
    assert svc.completions[tid] == actual
    cur = svc.mb.find_item(tid)
    assert cur is not None and cur.end == actual
    assert cur.node.key == it_b.node.key
    assert svc.mb.find_item(ev.backup_id) is None
    # the losing primary stays behind as a failed occupancy record
    losers = [i for seg in svc.mb.segments for i in seg.items
              if i.task.id == tid and i.failed]
    assert len(losers) == 1
    # no retry was spawned: the task COMPLETED (via its backup)
    assert all(r.task_id != tid for r in svc.stats.retries)
    _resolve_open_races(svc)
    combined = svc.drain()
    assert_fault_invariants(svc)
    assert_valid_schedule(combined, A100, tasks=tasks,
                          floors=service_floors(svc))


def test_primary_wins_cancels_backup_attempt():
    svc, tasks, tid = _speculating_service()
    [ev] = svc.stats.speculations
    t_done = svc.now + 1.0
    svc.report(tid, "completed", t=t_done, end=t_done)
    [ev] = [e for e in svc.stats.speculations if e.task_id == tid]
    assert ev.winner == "primary"
    assert svc.completions[tid] == t_done
    # the backup is gone from the live plan (removed if unstarted,
    # truncated to an occupancy record if it had begun)
    assert svc.mb.find_item(ev.backup_id) is None
    _resolve_open_races(svc)
    combined = svc.drain()
    assert_fault_invariants(svc)
    assert_valid_schedule(combined, A100, tasks=tasks,
                          floors=service_floors(svc))


def test_backup_failure_resolves_race_and_primary_survives():
    svc, tasks, tid = _speculating_service()
    [ev] = svc.stats.speculations
    it_b = svc.mb.find_item(ev.backup_id)
    t_fail = max(svc.now, it_b.begin + 0.1)
    svc.report(ev.backup_id, "failed", t=t_fail)
    # first race resolved "cancelled"; the still-straggling primary may
    # legitimately open a NEW race afterwards
    ev = [e for e in svc.stats.speculations if e.task_id == tid][0]
    assert ev.winner == "cancelled"
    # the backup is never retried in its own right
    assert all(r.task_id != ev.backup_id for r in svc.stats.retries)
    # the primary still runs and can complete normally
    t_done = svc.now + 1.0
    svc.report(tid, "completed", t=t_done, end=t_done)
    assert svc.completions[tid] == t_done
    _resolve_open_races(svc)
    svc.drain()
    assert_fault_invariants(svc)


def test_speculation_throttles_on_max_inflight_and_min_gain():
    # min_gain_s too large for any backup to promise: no race launches
    svc, _, _ = _speculating_service(
        speculation=SpeculationPolicy(min_gain_s=10_000.0))
    assert svc.stats.speculations == []
    # one race per task: a re-fired straggler never stacks backups
    svc2, _, tid2 = _speculating_service()
    assert len(svc2.stats.speculations) == 1
    it = svc2.mb.find_item(tid2)
    svc2.poll(svc2.now + 3.5 * it.planned_duration)
    assert len([e for e in svc2.stats.speculations
                if e.task_id == tid2 and e.winner is None]) <= 1


# --- speculation x outage interleavings ------------------------------------

def _cluster_race(seed=0):
    """Heterogeneous race: the straggling primary sits on the A30, the
    backup lands on the (faster) A100 — so either device can then be
    lost to probe both interleavings."""
    cs = cluster(A100, A30)
    tasks = _tasks(3, seed=seed)
    svc = SchedulingService(pool=cs, config=_cfg(
        straggler_factor=3.0, speculation=SpeculationPolicy(),
        retry=RetryPolicy(max_attempts=3, backoff_base=0.5)))
    for i, t in enumerate(tasks):
        svc.submit(t, arrival=float(i) * 0.1)
    svc.flush()
    it = min(svc.committed_items(), key=lambda it: it.begin)
    svc.poll(it.begin + 3.5 * it.planned_duration)
    [ev] = svc.stats.speculations
    tid = ev.task_id
    it_p = svc.mb.find_item(tid)
    it_b = svc.mb.find_item(ev.backup_id)
    pdev = svc.cluster.tree_device[it_p.node.tree]
    bdev = svc.cluster.tree_device[it_b.node.tree]
    assert pdev != bdev, "race must span two devices for the outage tests"
    return svc, tasks, ev, pdev, bdev


def test_outage_kills_backup_device_before_primary_resolves():
    svc, tasks, ev, pdev, bdev = _cluster_race()
    svc.quarantine(bdev, svc.now + 0.5)
    [sev] = [e for e in svc.stats.speculations if e.task_id == ev.task_id]
    assert sev.winner == "cancelled"
    assert svc.mb.find_item(ev.backup_id) is None
    # the backup is not stranded, not retried, not an outage casualty
    # to re-place: the primary is still the live hope
    assert all(r.task_id != ev.backup_id for r in svc.stats.retries)
    for oev in svc.stats.outages:
        assert ev.backup_id not in oev.withdrawn
    it_p = svc.mb.find_item(ev.task_id)
    assert it_p is not None and not it_p.failed
    t_done = svc.now + 1.0
    svc.report(ev.task_id, "completed", t=t_done, end=t_done)
    assert svc.completions[ev.task_id] == t_done
    _resolve_open_races(svc)
    svc.drain()
    assert_fault_invariants(svc)


def test_outage_kills_primary_device_backup_carries_the_task():
    svc, tasks, ev, pdev, bdev = _cluster_race()
    svc.quarantine(pdev, svc.now + 0.5)
    # the race is still open: the backup is the recovery, so the
    # primary's death spawns NO retry yet
    [sev] = [e for e in svc.stats.speculations if e.task_id == ev.task_id]
    assert sev.winner is None
    assert all(r.task_id != ev.task_id for r in svc.stats.retries)
    it_b = svc.mb.find_item(ev.backup_id)
    assert it_b is not None
    actual = max(svc.now, it_b.begin + 0.9 * it_b.planned_duration)
    svc.report(ev.backup_id, "completed", t=actual, end=actual)
    [sev] = [e for e in svc.stats.speculations if e.task_id == ev.task_id]
    assert sev.winner == "backup"
    assert svc.completions[ev.task_id] == actual
    _resolve_open_races(svc)
    svc.drain()
    assert_fault_invariants(svc)


def test_backup_dies_after_primary_died_routes_the_retry():
    svc, tasks, ev, pdev, bdev = _cluster_race()
    svc.quarantine(pdev, svc.now + 0.5)
    it_b = svc.mb.find_item(ev.backup_id)
    t_fail = max(svc.now, it_b.begin + 0.1)
    svc.report(ev.backup_id, "failed", t=t_fail)
    [sev] = [e for e in svc.stats.speculations if e.task_id == ev.task_id]
    assert sev.winner == "cancelled"
    # both attempts are dead: NOW the logical task re-enters the queue
    assert any(r.task_id == ev.task_id for r in svc.stats.retries)
    _resolve_open_races(svc)
    svc.drain()
    assert_fault_invariants(svc)
    again = svc.mb.find_item(ev.task_id)
    assert again is not None and not again.failed


# --- checkpoint / partial-progress credit ----------------------------------

def _checkpoint_service(period=1.0):
    svc = SchedulingService(A100, config=_cfg(
        min_batch=1, retry=RetryPolicy(max_attempts=3, backoff_base=0.5)))
    t = Task(id=1, times={1: 10.0, 2: 6.0, 3: 5.0, 4: 4.0, 7: 3.0},
             checkpoint_period_s=period)
    svc.submit(t, arrival=0.0)
    svc.flush()
    return svc, t, svc.mb.find_item(1)


def test_checkpoint_credit_shrinks_the_retry_to_the_remainder():
    svc, t, it = _checkpoint_service(period=1.0)
    planned = it.planned_duration
    # die 1.5 periods in: exactly ONE whole period is banked
    svc.report(1, "failed", t=it.begin + 1.5)
    [cp] = svc.stats.checkpoints
    assert cp.task_id == 1 and cp.attempt == 1
    assert cp.credit_s == pytest.approx(1.0)
    assert cp.progress == pytest.approx(1.0 / planned)
    svc.drain()
    it2 = svc.mb.find_item(1)
    # the retry is the REMAINDER, not a restart: every profile entry
    # scaled by the un-finished fraction
    frac = 1.0 - cp.progress
    for s, dur in t.times.items():
        assert it2.task.times[s] == pytest.approx(dur * frac)
    assert it2.planned_duration == pytest.approx(planned - 1.0)
    assert_fault_invariants(svc)


def test_checkpoint_credit_composes_across_failures_without_double_count():
    svc, t, it = _checkpoint_service(period=1.0)
    planned = it.planned_duration        # 3.0 at size 7
    svc.report(1, "failed", t=it.begin + 1.5)
    svc.drain()
    it2 = svc.mb.find_item(1)
    # second attempt (2.0s remainder) dies 1.2 periods in: one more
    # period banked, expressed on the ORIGINAL work — total 2/3
    svc.report(1, "failed", t=it2.begin + 1.2)
    cps = svc.stats.checkpoints
    assert len(cps) == 2
    assert cps[0].progress == pytest.approx(1.0 / planned)
    assert cps[1].progress == pytest.approx(2.0 / planned)
    assert cps[1].credit_s == pytest.approx(1.0)
    svc.drain()
    it3 = svc.mb.find_item(1)
    assert it3.planned_duration == pytest.approx(planned - 2.0)
    # and the third attempt completes: exactly-once accounting holds
    svc.report(1, "completed", t=it3.end, end=it3.end)
    assert svc.completions[1] == it3.end
    assert_fault_invariants(svc)


def test_no_checkpoint_period_restarts_from_zero():
    svc = SchedulingService(A100, config=_cfg(
        min_batch=1, retry=RetryPolicy(max_attempts=3, backoff_base=0.5)))
    t = Task(id=1, times={1: 10.0, 2: 6.0, 3: 5.0, 4: 4.0, 7: 3.0})
    svc.submit(t, arrival=0.0)
    svc.flush()
    it = svc.mb.find_item(1)
    planned = it.planned_duration
    svc.report(1, "failed", t=it.begin + 1.5)
    assert svc.stats.checkpoints == []
    svc.drain()
    it2 = svc.mb.find_item(1)
    assert it2.planned_duration == pytest.approx(planned)  # full restart


def test_checkpoint_period_must_be_positive():
    svc = SchedulingService(A100, config=_cfg())
    bad = Task(id=1, times={1: 5.0}, checkpoint_period_s=0.0)
    with pytest.raises(ValueError, match="checkpoint"):
        svc.submit(bad, arrival=0.0)


# --- correlated failure domains --------------------------------------------

def test_domain_outage_draws_are_deterministic_and_disjoint():
    spec = FaultSpec(seed=5, domains=((0, 1), (2,)), domain_mtbf_s=40.0,
                     domain_repair_s=5.0, max_domain_shocks=3)
    w = FaultInjector(spec).domain_outages(0, 500.0)
    assert w == FaultInjector(spec).domain_outages(0, 500.0)
    assert w, "MTBF 40s over 500s must shock at least once"
    for (lost, rec) in w:
        assert 0.0 <= lost < 500.0 and rec == pytest.approx(lost + 5.0)
    for (_, ra), (b, _) in zip(w, w[1:]):
        assert ra <= b, "shock windows of one domain must be disjoint"
    # distinct domains draw from distinct streams
    assert FaultInjector(spec).domain_outages(1, 500.0) != w
    # an undomained spec never shocks
    assert FaultInjector(FaultSpec(seed=5)).domain_outages(0, 500.0) == []


def test_fault_spec_validates_domains():
    with pytest.raises(ValueError, match="domain_mtbf_s"):
        FaultSpec(domain_mtbf_s=0.0)
    with pytest.raises(ValueError, match="non-empty"):
        FaultSpec(domains=((0,), ()), domain_mtbf_s=10.0)


def test_joint_domain_quarantine_repartitions_on_the_survivor():
    cs = cluster(A100, A30, A30)
    tasks = _tasks(12, seed=3)
    svc = SchedulingService(pool=cs, config=_cfg(
        retry=RetryPolicy(max_attempts=3)))
    for i, t in enumerate(tasks):
        svc.submit(t, arrival=i * 0.2)
    svc.flush()
    t0 = svc.now + 1.0
    svc.quarantine([1, 2], t0)
    assert sorted(ev.device for ev in svc.stats.outages) == [1, 2]
    assert all(ev.lost_at == t0 for ev in svc.stats.outages)
    # everything live after the shock sits on the lone survivor
    for it in svc.committed_items():
        if it.begin >= t0:
            assert svc.cluster.tree_device[it.node.tree] == 0
    # a second shock on an already-dark member is a no-op, not an error
    assert svc.quarantine([2], t0 + 0.5) == []
    svc.recover([1, 2], t0 + 10.0)
    svc.drain()
    assert_fault_invariants(svc)
    resolved = (set(svc.completions) | set(svc.stats.failed)
                | set(svc.stats.rejected)
                | {it.task.id for it in svc.committed_items()})
    assert {t.id for t in tasks} <= resolved


def test_correlated_domain_outage_end_to_end():
    spec = FaultSpec(seed=3, domains=((1, 2),), domain_mtbf_s=25.0,
                     domain_repair_s=8.0)
    cs = cluster(A100, A30, A30)
    tasks = _tasks(14, seed=7)
    stream = _stream(tasks, gap=1.0)
    svc = SchedulingService(pool=cs, config=_cfg(
        retry=RetryPolicy(max_attempts=3)))
    rep = run_with_faults(svc, stream, injector=FaultInjector(spec))
    # both domain members go down and come back TOGETHER, twice
    by_time: dict[float, set] = {}
    for ev in svc.stats.outages:
        by_time.setdefault(ev.lost_at, set()).add(ev.device)
    assert by_time and all(devs == {1, 2} for devs in by_time.values())
    assert_fault_invariants(svc)
    resolved = set(rep.completions) | set(rep.failed) | set(svc.stats.rejected)
    assert resolved == {t.id for t in tasks}


# --- online profile calibration --------------------------------------------

def test_calibration_learns_a_systematic_bias_between_waves():
    import dataclasses as dc

    svc = SchedulingService(A100, config=_cfg(
        calibration=ProfileCalibration()))
    w1 = _tasks(4, seed=11)
    ids1 = {t.id for t in w1}
    for i, t in enumerate(w1):
        svc.submit(t, arrival=i * 0.1)
    svc.flush()
    # wave 1 systematically runs 1.5x its profile; report in actual-end
    # order (each correction may replan the survivors, so re-fetch)
    while True:
        live = [it for it in svc.committed_items()
                if it.task.id in ids1 and it.task.id not in svc.completions]
        if not live:
            break
        nxt = min(live, key=lambda it: it.begin + 1.5 * svc.true_duration(it))
        actual = nxt.begin + 1.5 * svc.true_duration(nxt)
        svc.report(nxt.task.id, "completed", t=max(svc.now, actual),
                   end=actual)
    assert svc.config.calibration.observations == 4
    # wave 2 re-submits the same task FAMILIES (same names): the planner
    # now budgets the learned 1.5x, while the stored profiles stay raw
    w2 = [dc.replace(t, id=t.id + 100) for t in w1]
    for i, t in enumerate(w2):
        svc.submit(t, arrival=svc.now + i * 0.1)
    svc.flush()
    placed = [it for it in svc.committed_items() if it.task.id >= 100]
    assert len(placed) == 4
    for it in placed:
        assert it.planned_duration == pytest.approx(
            1.5 * svc.true_duration(it))


def test_fresh_calibration_plans_bit_identical():
    tasks = _tasks(10, seed=17)
    ref = SchedulingService(A100, config=_cfg())
    svc = SchedulingService(A100, config=_cfg(
        calibration=ProfileCalibration()))
    for s in (ref, svc):
        for i, t in enumerate(tasks):
            s.submit(t, arrival=i * 0.3)
        s.drain()
    assert _plan_signature(svc) == _plan_signature(ref)


def test_calibration_validation():
    with pytest.raises(ValueError, match="alpha"):
        ProfileCalibration(alpha=0.0)


# --- profile transfer fallback ---------------------------------------------

def test_transfer_profile_fills_sizes_and_unmeasured_kinds():
    t = Task(id=1, times=Profile({"A100": {2: 6.0}}))
    out = transfer_profile(
        t, {"A100": (1, 2, 4), "A30": (1, 2)},
        speed={"A100": 1.0, "A30": 0.5})
    a100 = dict(out.times.for_kind("A100"))
    # measured entry untouched; s < s0 upscaled by s0/s; s > s0 kept
    assert a100[2] == 6.0
    assert a100[1] == pytest.approx(12.0)
    assert a100[4] == pytest.approx(6.0)
    # the A30 copies the donor scaled by relative speed (2x slower)
    a30 = dict(out.times.for_kind("A30"))
    assert a30[2] == pytest.approx(12.0)
    assert a30[1] == pytest.approx(24.0)
    # identity for a task that already covers the fleet
    full = Task(id=2, times=Profile({"A100": {1: 3.0, 2: 2.0}}))
    same = transfer_profile(full, {"A100": (1, 2)})
    assert dict(same.times.for_kind("A100")) == {1: 3.0, 2: 2.0}


def test_transfer_profile_raises_only_when_nothing_is_measured():
    empty = Task(id=9, times=Profile({"A100": {}}))
    with pytest.raises(ProfileCoverageError, match="no measured entries"):
        transfer_profile(empty, {"A100": (1, 2)})


def test_profile_transfer_gates_admission_at_the_service():
    partial = Task(id=50, times=Profile({"A100": {1: 8.0, 2: 5.0}}))
    # off: no device fully covers the profile -> rejected at flush
    svc = SchedulingService(pool=cluster(A100, A30), config=_cfg())
    svc.submit(partial, arrival=0.0)
    svc.drain()
    assert svc.stats.rejected == [50]
    # on: missing entries are derived at intake and the task is served
    svc2 = SchedulingService(pool=cluster(A100, A30), config=_cfg(
        min_batch=1, profile_transfer=True))
    svc2.submit(partial, arrival=0.0)
    svc2.flush()
    it = svc2.mb.find_item(50)
    assert it is not None
    stored = svc2._tasks[50].times
    assert set(stored.for_kind("A100")) == {1, 2, 3, 4, 7}
    assert set(stored.for_kind("A30")) == {1, 2, 4}
    # measured entries always win
    assert stored.for_kind("A100")[1] == 8.0


# --- all mechanisms armed but idle == PR 6 bit-identical -------------------

def test_all_mechanisms_armed_but_idle_plan_bit_identical():
    tasks = _tasks(12, seed=9)
    stream = _stream(tasks)
    ref = SchedulingService(A100, config=_cfg(replan=True))
    for a, t, dl in stream:
        ref.submit(t, arrival=a, deadline=dl)
    ref.drain()
    svc = SchedulingService(A100, config=_cfg(
        replan=True, straggler_factor=3.0,
        retry=RetryPolicy(max_attempts=3),
        speculation=SpeculationPolicy(),
        calibration=ProfileCalibration(),
        profile_transfer=True))
    run_with_faults(svc, stream, injector=FaultInjector())
    assert _plan_signature(svc) == _plan_signature(ref)
    assert svc.stats.speculations == [] and svc.stats.checkpoints == []


def test_all_mechanisms_armed_but_idle_cluster_bit_identical():
    tasks = _tasks(10, seed=13)
    stream = _stream(tasks)
    ref = SchedulingService(pool=cluster(A100, A30), config=_cfg())
    for a, t, dl in stream:
        ref.submit(t, arrival=a, deadline=dl)
    ref.drain()
    svc = SchedulingService(pool=cluster(A100, A30), config=_cfg(
        straggler_factor=3.0, retry=RetryPolicy(max_attempts=3),
        speculation=SpeculationPolicy(),
        calibration=ProfileCalibration(),
        profile_transfer=True))
    run_with_faults(svc, stream, injector=FaultInjector())
    assert _plan_signature(svc) == _plan_signature(ref)
    assert svc.stats.speculations == [] and svc.stats.checkpoints == []


# --- recovery boundary regressions -----------------------------------------

def test_rebuild_tail_reset_boundary_is_inclusive():
    """An instance whose latest creation BEGAN exactly at ``reset_at``
    is legitimate post-recovery work and must survive the reset; one
    whose creation began any earlier was aborted by the outage and must
    die even though its busy-until extends past the reset."""
    from repro.core import MultiBatchScheduler

    mb = MultiBatchScheduler(A100)
    mb.add_batch(_tasks(6, seed=5))
    created: dict = {}
    for seg in mb.segments:
        for rc in seg.reconfigs:
            if rc.kind == "create":
                prev = created.get(rc.node.key)
                if prev is None or rc.begin > prev:
                    created[rc.node.key] = rc.begin
    cand = [(k, b) for k, b in created.items()
            if k in mb.tail.alive and mb.tail.alive[k] > b + 1e-3
            and b > 0.0]
    assert cand, "plan must keep at least one created instance alive"
    key, born = max(cand, key=lambda kb: kb[1])
    # boundary-inclusive: begin == reset_at survives
    mb.reset_at = born
    mb.rebuild_tail()
    assert key in mb.tail.alive
    # creation began strictly before the reset: aborted by the outage
    mb.reset_at = born + 1e-6
    mb.rebuild_tail()
    assert key not in mb.tail.alive


def test_quarantine_arriving_mid_reconfiguration_window():
    cs = cluster(A100, A30)
    tasks = _tasks(10, seed=3)
    svc = SchedulingService(pool=cs, config=_cfg(
        retry=RetryPolicy(max_attempts=3)))
    for i, t in enumerate(tasks):
        svc.submit(t, arrival=i * 0.2)
    svc.flush()
    windows = [
        (svc.cluster.tree_device[rc.node.tree], rc)
        for seg in svc.mb.segments for rc in seg.reconfigs
        if rc.begin > svc.now + 1e-9 and rc.end > rc.begin + 1e-9
    ]
    assert windows, "plan must contain a future reconfiguration window"
    dev, rc = min(windows, key=lambda w: w[1].begin)
    mid = 0.5 * (rc.begin + rc.end)
    withdrawn = svc.quarantine(dev, mid)
    [oev] = svc.stats.outages
    assert oev.device == dev and oev.lost_at == mid
    svc.recover(dev, mid + 20.0)
    more = _tasks(4, seed=77, id_offset=500)
    for t in more:
        svc.submit(t, arrival=svc.now + 0.1)
    svc.flush()
    svc.drain()
    assert_fault_invariants(svc)
    resolved = (set(svc.completions) | set(svc.stats.failed)
                | set(svc.stats.rejected)
                | {it.task.id for it in svc.committed_items()})
    want = {t.id for t in tasks} | {t.id for t in more}
    assert want <= resolved
