"""SchedulingService semantics: latency-budget flushing, online fallback
for slow trickles, determinism, and tail reuse across flushes."""

import pytest

from repro.core import (
    A100,
    SchedulerConfig,
    SchedulingService,
    get_policy,
    validate_schedule,
)
from repro.core.synth import generate_tasks, workload


def _tasks(n, seed=0):
    return generate_tasks(n, A100, workload("mixed", "wide", A100), seed=seed)


def _cfg(**kw):
    base = dict(max_wait_s=10.0, max_batch=32, min_batch=2)
    base.update(kw)
    return SchedulerConfig(**base)


def test_arrivals_within_budget_batch_together():
    tasks = _tasks(8)
    svc = SchedulingService(A100, config=_cfg())
    # six tasks inside one 10s window, then one arrival past the deadline
    for i, t in enumerate(tasks[:6]):
        svc.submit(t, arrival=float(i))          # t = 0..5
    assert svc.stats.batches == 0                # budget not yet expired
    svc.submit(tasks[6], arrival=30.0)           # deadline 0+10 passed
    assert svc.stats.batches == 1
    batch_decisions = [d for d in svc.stats.decisions if d.route == "batch"]
    assert {d.task_id for d in batch_decisions} == {t.id for t in tasks[:6]}
    # all six were decided together at the first task's deadline
    assert {d.decided_at for d in batch_decisions} == {10.0}
    assert all(d.queue_delay <= 10.0 + 1e-9 for d in batch_decisions)
    svc.submit(tasks[7], arrival=31.0)
    combined = svc.drain()
    validate_schedule(combined, tasks, check_reconfig=False)
    assert svc.stats.batches == 2


def test_slow_trickle_falls_back_to_online_placement():
    tasks = _tasks(5, seed=2)
    svc = SchedulingService(A100, config=_cfg(max_wait_s=5.0))
    for i, t in enumerate(tasks):
        svc.submit(t, arrival=i * 100.0)         # gaps far beyond the budget
    combined = svc.drain()
    validate_schedule(combined, tasks, check_reconfig=False)
    assert svc.stats.batches == 0
    assert svc.stats.online_placements == len(tasks)
    assert all(d.route == "online" for d in svc.stats.decisions)


def test_max_batch_flushes_early():
    tasks = _tasks(4, seed=1)
    svc = SchedulingService(A100, config=_cfg(max_batch=4))
    for t in tasks:
        svc.submit(t, arrival=0.0)               # same instant: budget never expires
    assert svc.stats.batches == 1                # size cap fired instead
    assert svc.stats.decisions[0].queue_delay == 0.0


def test_urgent_bypasses_the_budget():
    tasks = _tasks(3, seed=4)
    svc = SchedulingService(A100, config=_cfg())
    svc.submit(tasks[0], arrival=0.0)
    svc.submit(tasks[1], arrival=1.0, urgent=True)
    assert svc.stats.online_placements == 1      # placed immediately
    assert len(svc.pending) == 1                 # the queued task stays queued
    svc.submit(tasks[2], arrival=2.0)
    combined = svc.drain()
    validate_schedule(combined, tasks, check_reconfig=False)


def test_deterministic_under_fixed_seed():
    def run():
        svc = SchedulingService(A100, config=_cfg(max_wait_s=3.0))
        arrival = 0.0
        for i, t in enumerate(_tasks(14, seed=9)):
            arrival += 0.5 if i % 7 else 20.0
            svc.submit(t, arrival=arrival)
        combined = svc.drain()
        return (
            svc.makespan,
            svc.stats.batches,
            svc.stats.online_placements,
            sorted((it.task.id, it.node.key, it.begin) for it in combined.items),
        )

    assert run() == run()


def test_tail_reuse_across_consecutive_flushes():
    tasks = _tasks(12, seed=5)
    svc = SchedulingService(A100, config=_cfg(max_wait_s=2.0))
    for i, t in enumerate(tasks):
        # two dense bursts separated by a long gap -> two batch flushes
        svc.submit(t, arrival=(0.0 if i < 6 else 100.0) + 0.1 * i)
    combined = svc.drain()
    assert svc.stats.batches == 2
    assert len(svc.mb.segments) == 2
    # the second flush was planned against the first one's tail: its tasks
    # never overlap the committed work (the combined schedule is feasible)
    validate_schedule(combined, tasks, check_reconfig=False)
    seg1, seg2 = svc.mb.segments
    assert min(it.begin for it in seg2.items) >= 0.0
    assert svc.tail.release != {k: 0.0 for k in svc.tail.release}
    # offline FAR on everything at once is the floor for the split stream
    offline = get_policy("far").plan(tasks, A100).makespan
    assert svc.makespan >= offline - 1e-6


def test_placements_never_precede_arrival_or_decision():
    """The combined timeline is causal: no task starts before the flush
    decision that placed it (and hence before its own arrival) — on both
    the batch path and the online-fallback path."""
    tasks = _tasks(7, seed=11)
    svc = SchedulingService(A100, config=_cfg(max_wait_s=5.0))
    arrivals = [0.0, 1.0, 2.0, 200.0, 400.0, 600.0, 800.0]
    for t, a in zip(tasks, arrivals):
        svc.submit(t, arrival=a)
    combined = svc.drain()
    validate_schedule(combined, tasks, check_reconfig=False)
    assert svc.stats.batches >= 1 and svc.stats.online_placements >= 1
    decided = {d.task_id: d.decided_at for d in svc.stats.decisions}
    arrived = {t.id: a for t, a in zip(tasks, arrivals)}
    for it in combined.items:
        assert it.begin >= arrived[it.task.id] - 1e-9
        assert it.begin >= decided[it.task.id] - 1e-9


def test_arrivals_must_be_non_decreasing():
    svc = SchedulingService(A100, config=_cfg())
    t1, t2 = _tasks(2, seed=6)
    svc.submit(t1, arrival=10.0)
    with pytest.raises(ValueError, match="non-decreasing"):
        svc.submit(t2, arrival=5.0)


def test_multi_gpu_pool():
    svc = SchedulingService(
        A100, config=_cfg(max_batch=6), pool_size=2
    )
    assert svc.spec.n_slices == 2 * A100.n_slices
    tasks = generate_tasks(
        6, svc.spec, workload("mixed", "wide", svc.spec), seed=0
    )
    for t in tasks:
        svc.submit(t, arrival=0.0)
    combined = svc.drain()
    validate_schedule(combined, tasks, check_reconfig=False)
    # both trees host work: the pool is actually used
    assert {it.node.tree for it in combined.items} == {0, 1}


def test_mixed_batch_and_online_share_one_timeline():
    """A batch flush, then a trickle fallback, then another batch — all
    three segments must coexist feasibly (the online fallback is seeded
    with the committed tail)."""
    tasks = _tasks(11, seed=8)
    svc = SchedulingService(A100, config=_cfg(max_wait_s=6.0))
    arrivals = [0, 1, 2, 3, 4,          # burst -> batch
                50,                     # lone straggler -> online fallback
                100, 101, 102, 103, 104]  # second burst -> batch
    for t, arr in zip(tasks, arrivals):
        svc.submit(t, arrival=float(arr))
    combined = svc.drain()
    validate_schedule(combined, tasks, check_reconfig=False)
    assert svc.stats.batches == 2
    assert svc.stats.online_placements == 1
    routes = {d.task_id: d.route for d in svc.stats.decisions}
    assert routes[tasks[5].id] == "online"
