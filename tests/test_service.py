"""SchedulingService semantics: latency-budget flushing, online fallback
for slow trickles, determinism, tail reuse across flushes, per-task
deadlines + admission control, and tail re-planning."""

import pytest

from invariants import assert_valid_schedule, service_floors
from repro.core import (
    A100,
    SchedulerConfig,
    SchedulingService,
    Task,
    get_policy,
    validate_schedule,
)
from repro.core.synth import generate_tasks, workload


def _tasks(n, seed=0):
    return generate_tasks(n, A100, workload("mixed", "wide", A100), seed=seed)


def _cfg(**kw):
    base = dict(max_wait_s=10.0, max_batch=32, min_batch=2)
    base.update(kw)
    return SchedulerConfig(**base)


def test_arrivals_within_budget_batch_together():
    tasks = _tasks(8)
    svc = SchedulingService(A100, config=_cfg())
    # six tasks inside one 10s window, then one arrival past the deadline
    for i, t in enumerate(tasks[:6]):
        svc.submit(t, arrival=float(i))          # t = 0..5
    assert svc.stats.batches == 0                # budget not yet expired
    svc.submit(tasks[6], arrival=30.0)           # deadline 0+10 passed
    assert svc.stats.batches == 1
    batch_decisions = [d for d in svc.stats.decisions if d.route == "batch"]
    assert {d.task_id for d in batch_decisions} == {t.id for t in tasks[:6]}
    # all six were decided together at the first task's deadline
    assert {d.decided_at for d in batch_decisions} == {10.0}
    assert all(d.queue_delay <= 10.0 + 1e-9 for d in batch_decisions)
    svc.submit(tasks[7], arrival=31.0)
    combined = svc.drain()
    validate_schedule(combined, tasks, check_reconfig=False)
    assert svc.stats.batches == 2


def test_slow_trickle_falls_back_to_online_placement():
    tasks = _tasks(5, seed=2)
    svc = SchedulingService(A100, config=_cfg(max_wait_s=5.0))
    for i, t in enumerate(tasks):
        svc.submit(t, arrival=i * 100.0)         # gaps far beyond the budget
    combined = svc.drain()
    validate_schedule(combined, tasks, check_reconfig=False)
    assert svc.stats.batches == 0
    assert svc.stats.online_placements == len(tasks)
    assert all(d.route == "online" for d in svc.stats.decisions)


def test_max_batch_flushes_early():
    tasks = _tasks(4, seed=1)
    svc = SchedulingService(A100, config=_cfg(max_batch=4))
    for t in tasks:
        svc.submit(t, arrival=0.0)               # same instant: budget never expires
    assert svc.stats.batches == 1                # size cap fired instead
    assert svc.stats.decisions[0].queue_delay == 0.0


def test_urgent_bypasses_the_budget():
    tasks = _tasks(3, seed=4)
    svc = SchedulingService(A100, config=_cfg())
    svc.submit(tasks[0], arrival=0.0)
    svc.submit(tasks[1], arrival=1.0, urgent=True)
    assert svc.stats.online_placements == 1      # placed immediately
    assert len(svc.pending) == 1                 # the queued task stays queued
    svc.submit(tasks[2], arrival=2.0)
    combined = svc.drain()
    validate_schedule(combined, tasks, check_reconfig=False)


def test_deterministic_under_fixed_seed():
    def run():
        svc = SchedulingService(A100, config=_cfg(max_wait_s=3.0))
        arrival = 0.0
        for i, t in enumerate(_tasks(14, seed=9)):
            arrival += 0.5 if i % 7 else 20.0
            svc.submit(t, arrival=arrival)
        combined = svc.drain()
        return (
            svc.makespan,
            svc.stats.batches,
            svc.stats.online_placements,
            sorted((it.task.id, it.node.key, it.begin) for it in combined.items),
        )

    assert run() == run()


def test_tail_reuse_across_consecutive_flushes():
    tasks = _tasks(12, seed=5)
    svc = SchedulingService(A100, config=_cfg(max_wait_s=2.0))
    for i, t in enumerate(tasks):
        # two dense bursts separated by a long gap -> two batch flushes
        svc.submit(t, arrival=(0.0 if i < 6 else 100.0) + 0.1 * i)
    combined = svc.drain()
    assert svc.stats.batches == 2
    assert len(svc.mb.segments) == 2
    # the second flush was planned against the first one's tail: its tasks
    # never overlap the committed work (the combined schedule is feasible)
    validate_schedule(combined, tasks, check_reconfig=False)
    seg1, seg2 = svc.mb.segments
    assert min(it.begin for it in seg2.items) >= 0.0
    assert svc.tail.release != {k: 0.0 for k in svc.tail.release}
    # offline FAR on everything at once is the floor for the split stream
    offline = get_policy("far").plan(tasks, A100).makespan
    assert svc.makespan >= offline - 1e-6


def test_placements_never_precede_arrival_or_decision():
    """The combined timeline is causal: no task starts before the flush
    decision that placed it (and hence before its own arrival) — on both
    the batch path and the online-fallback path."""
    tasks = _tasks(7, seed=11)
    svc = SchedulingService(A100, config=_cfg(max_wait_s=5.0))
    arrivals = [0.0, 1.0, 2.0, 200.0, 400.0, 600.0, 800.0]
    for t, a in zip(tasks, arrivals):
        svc.submit(t, arrival=a)
    combined = svc.drain()
    validate_schedule(combined, tasks, check_reconfig=False)
    assert svc.stats.batches >= 1 and svc.stats.online_placements >= 1
    decided = {d.task_id: d.decided_at for d in svc.stats.decisions}
    arrived = {t.id: a for t, a in zip(tasks, arrivals)}
    for it in combined.items:
        assert it.begin >= arrived[it.task.id] - 1e-9
        assert it.begin >= decided[it.task.id] - 1e-9


def test_arrivals_must_be_non_decreasing():
    svc = SchedulingService(A100, config=_cfg())
    t1, t2 = _tasks(2, seed=6)
    svc.submit(t1, arrival=10.0)
    with pytest.raises(ValueError, match="non-decreasing"):
        svc.submit(t2, arrival=5.0)


def test_multi_gpu_pool():
    svc = SchedulingService(
        A100, config=_cfg(max_batch=6), pool_size=2
    )
    assert svc.spec.n_slices == 2 * A100.n_slices
    tasks = generate_tasks(
        6, svc.spec, workload("mixed", "wide", svc.spec), seed=0
    )
    for t in tasks:
        svc.submit(t, arrival=0.0)
    combined = svc.drain()
    validate_schedule(combined, tasks, check_reconfig=False)
    # both trees host work: the pool is actually used
    assert {it.node.tree for it in combined.items} == {0, 1}


def _items(schedule):
    return sorted(
        (it.task.id, it.node.key, it.begin, it.size) for it in schedule.items
    )


def _run_stream(tasks, arrivals, deadlines=None, **cfg_kw):
    svc = SchedulingService(A100, config=_cfg(**cfg_kw))
    deadlines = deadlines or {}
    for t, a in zip(tasks, arrivals):
        svc.submit(t, arrival=float(a), deadline=deadlines.get(t.id))
    combined = svc.drain()
    return svc, combined


# -- deadlines + admission ---------------------------------------------------

def test_deadline_tracking_and_report():
    tasks = _tasks(6, seed=3)
    arrivals = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5]
    # generous deadline for every task except one that is sure to miss
    deadlines = {t.id: 1e6 for t in tasks}
    victim = tasks[3]
    deadlines[victim.id] = arrivals[3] + 1e-6
    svc, combined = _run_stream(tasks, arrivals, deadlines, max_wait_s=1.0)
    validate_schedule(combined, tasks, check_reconfig=False)
    rep = svc.deadline_report()
    assert rep["tracked"] == 6
    assert rep["missed"] == [victim.id]
    assert rep["miss_rate"] == pytest.approx(1 / 6)
    assert rep["rejected"] == [] and rep["demoted"] == []
    # every decision carries the task's retained deadline
    by_task = {d.task_id: d.deadline for d in svc.stats.decisions}
    assert by_task[victim.id] == deadlines[victim.id]


def test_admission_reject_provably_unmeetable():
    tasks = _tasks(3, seed=4)
    svc = SchedulingService(A100, config=_cfg(admission="reject"))
    # deadline before the task's best-case completion: provably unmeetable
    best = min(tasks[0].times.values())
    assert svc.submit(tasks[0], arrival=5.0, deadline=5.0 + best / 2) \
        == "rejected"
    assert svc.stats.rejected == [tasks[0].id]
    # a meetable deadline is admitted
    assert svc.submit(tasks[1], arrival=5.0, deadline=5.0 + 10 * best) \
        == "queued"
    svc.submit(tasks[2], arrival=6.0)
    combined = svc.drain()
    # the rejected task is nowhere in the committed timeline
    validate_schedule(combined, tasks[1:], check_reconfig=False)
    assert svc.deadline_report()["rejected"] == [tasks[0].id]


def test_admission_demote_keeps_task_best_effort():
    tasks = _tasks(2, seed=5)
    svc = SchedulingService(A100, config=_cfg(admission="demote"))
    best = min(tasks[0].times.values())
    assert svc.submit(tasks[0], arrival=0.0, deadline=best / 2) == "demoted"
    svc.submit(tasks[1], arrival=0.1)
    combined = svc.drain()
    # demoted = still scheduled, but its deadline no longer tracked
    validate_schedule(combined, tasks, check_reconfig=False)
    rep = svc.deadline_report()
    assert rep["tracked"] == 0 and rep["demoted"] == [tasks[0].id]


def test_admission_lower_bound_sees_running_work():
    """The admission floor tightens with the running (never-preemptible)
    occupancy of the committed timeline: a whole-GPU task running now
    pushes every later completion past its end."""
    hog = Task(id=900, times={7: 1000.0})   # only moldable to the full GPU
    probe = Task(id=901, times={s: 10.0 - s for s in A100.sizes})
    svc = SchedulingService(A100, config=_cfg(admission="reject"))
    svc.submit(hog, arrival=0.0, urgent=True)   # occupies slices for ~1000s
    hog_end = max(it.end for it in svc.mb.combined_schedule().items)
    lb = svc.completion_lower_bound(probe, at=1.0)
    assert lb >= hog_end  # no slice clears before the hog finishes
    assert svc.submit(probe, arrival=1.0, deadline=hog_end / 2) == "rejected"
    # without the deadline the same task is admitted fine
    assert svc.submit(probe, arrival=2.0) == "queued"


def test_flush_plan_carries_deadline_extras():
    tasks = _tasks(4, seed=6)
    deadlines = {t.id: 100.0 + i for i, t in enumerate(tasks)}
    svc = SchedulingService(A100, config=_cfg(max_batch=4))
    for t in tasks:
        svc.submit(t, arrival=0.0, deadline=deadlines[t.id])
    assert svc.stats.batches == 1
    plan = svc.mb.results[-1]
    assert plan.extras["deadlines"] == deadlines
    ends = {it.task.id: it.end for it in svc.mb.segments[-1].items}
    assert plan.extras["deadline_slack"] == {
        tid: deadlines[tid] - ends[tid] for tid in deadlines
    }


# -- tail re-planning --------------------------------------------------------

def _bursty(n=18, seed=12):
    """Two dense bursts: the second one lands while the first's tail is
    still queued, so re-planning has something to pull back."""
    tasks = _tasks(n, seed=seed)
    arrivals = [0.1 * i if i < n // 2 else 1.0 + 0.1 * i for i in range(n)]
    return tasks, arrivals


def test_replan_never_worse_than_plain_on_bursty_stream():
    tasks, arrivals = _bursty()
    svc_plain, c_plain = _run_stream(tasks, arrivals,
                                     max_wait_s=1.0, max_batch=6)
    svc_re, c_re = _run_stream(tasks, arrivals,
                               max_wait_s=1.0, max_batch=6, replan=True)
    validate_schedule(c_re, tasks, check_reconfig=False)
    assert svc_re.makespan <= svc_plain.makespan + 1e-9
    assert svc_re.stats.replan_attempts >= 1


def test_replan_win_pulls_back_only_unstarted_work():
    tasks, arrivals = _bursty()
    svc, combined = _run_stream(tasks, arrivals,
                                max_wait_s=1.0, max_batch=6, replan=True)
    assert_valid_schedule(combined, A100, tasks=tasks,
                          floors=service_floors(svc))
    assert svc.stats.replan_wins >= 1
    for ev in svc.stats.replan_events:
        assert ev.makespan_replanned < ev.makespan_plain
        assert ev.win > 0
        # every pulled-back task was re-decided at the flush time
        redecided = {
            d.task_id for d in svc.stats.decisions
            if d.flush_id == ev.flush_id and d.route == "replan"
        }
        assert redecided == set(ev.withdrawn)
    # a withdrawn task's final placement never starts before the flush
    # decision that re-planned it (the re-plan's causal floor)
    last_decision = {}
    for d in svc.stats.decisions:
        last_decision[d.task_id] = d.decided_at
    for it in svc.mb.combined_schedule().items:
        assert it.begin >= last_decision[it.task.id] - 1e-9


def test_replan_identical_to_plain_when_nothing_queued():
    """A single flush has no committed tail to revisit: replan=True must
    be bit-identical to replan=False."""
    tasks = _tasks(6, seed=8)
    svc_plain, c_plain = _run_stream(tasks, [0.0] * 6, max_batch=6)
    svc_re, c_re = _run_stream(tasks, [0.0] * 6, max_batch=6, replan=True)
    assert _items(c_plain) == _items(c_re)
    assert svc_re.stats.replan_attempts == 0
    assert svc_re.stats.replan_wins == 0


def test_replan_running_tasks_keep_their_times():
    """Across every flush, items already started on the primary chain are
    never moved: the no-preemption model survives re-planning."""
    tasks, arrivals = _bursty(n=14, seed=13)
    svc = SchedulingService(
        A100, config=_cfg(max_wait_s=1.0, max_batch=5, replan=True)
    )
    prev_items, prev_flushes = [], 0
    for t, a in zip(tasks, arrivals):
        svc.submit(t, arrival=float(a))
        flushes = svc._flush_id
        if flushes > prev_flushes:
            decided = [
                d.decided_at for d in svc.stats.decisions
                if d.flush_id > prev_flushes
            ]
            cutoff = min(decided)
            now_items = set(_items(svc.mb.combined_schedule()))
            for item in prev_items:
                if item[2] <= cutoff + 1e-9:  # had started by the decision
                    assert item in now_items
        prev_flushes = flushes
        prev_items = _items(svc.mb.combined_schedule())
    combined = svc.drain()
    validate_schedule(combined, tasks, check_reconfig=False)


def test_mixed_batch_and_online_share_one_timeline():
    """A batch flush, then a trickle fallback, then another batch — all
    three segments must coexist feasibly (the online fallback is seeded
    with the committed tail)."""
    tasks = _tasks(11, seed=8)
    svc = SchedulingService(A100, config=_cfg(max_wait_s=6.0))
    arrivals = [0, 1, 2, 3, 4,          # burst -> batch
                50,                     # lone straggler -> online fallback
                100, 101, 102, 103, 104]  # second burst -> batch
    for t, arr in zip(tasks, arrivals):
        svc.submit(t, arrival=float(arr))
    combined = svc.drain()
    validate_schedule(combined, tasks, check_reconfig=False)
    assert svc.stats.batches == 2
    assert svc.stats.online_placements == 1
    routes = {d.task_id: d.route for d in svc.stats.decisions}
    assert routes[tasks[5].id] == "online"
