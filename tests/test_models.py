"""Per-architecture smoke tests (reduced configs, real forward/train
steps, shape + NaN assertions) and decode-vs-prefill consistency."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SMOKES
from repro.models.model import build_model


def _batch(cfg, b=2, s=32):
    out = {
        "tokens": jax.random.randint(jax.random.key(1), (b, s), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.key(2), (b, s), 0,
                                     cfg.vocab_size),
    }
    if cfg.is_encoder_decoder:
        out["frames"] = jax.random.normal(
            jax.random.key(3), (b, cfg.encoder_frames, cfg.d_model),
            jnp.bfloat16,
        )
    return out


@pytest.mark.parametrize("name", sorted(SMOKES))
def test_smoke_forward_and_train_step(name):
    cfg = SMOKES[name]
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), name
    leaves = jax.tree.leaves(grads)
    assert leaves
    for g in leaves:
        assert not bool(jnp.isnan(g.astype(jnp.float32)).any()), name


@pytest.mark.parametrize("name", sorted(SMOKES))
def test_smoke_prefill_decode_shapes(name):
    cfg = SMOKES[name]
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b, s = 2, 32
    batch = _batch(cfg, b, s)
    pre_in = {k: v for k, v in batch.items() if k != "labels"}
    logits, cache = model.prefill(params, pre_in)
    assert logits.shape == (b, 1, cfg.padded_vocab())
    tok = jnp.ones((b, 1), jnp.int32)
    logits2, cache2 = model.decode_step(params, cache, tok)
    assert logits2.shape == (b, 1, cfg.padded_vocab())
    assert int(cache2["pos"]) == s + 1
    assert not bool(jnp.isnan(logits2.astype(jnp.float32)).any()), name


# decode-vs-prefill agreement: run in f32 so path divergence is visible
# only as true math errors (bf16 tested separately at looser tolerance)
@pytest.mark.parametrize("name", [
    "qwen2.5-3b", "gemma3-12b", "qwen2-moe-a2.7b", "xlstm-350m",
    "zamba2-2.7b", "whisper-small",
])
def test_decode_matches_prefill_f32(name):
    from repro.models import layers

    old = layers.DTYPE
    layers.DTYPE = jnp.float32
    try:
        cfg = SMOKES[name]
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 36), 0,
                                  cfg.vocab_size)
        pre = {"tokens": toks[:, :32]}
        full = {"tokens": toks}
        if cfg.is_encoder_decoder:
            frames = jax.random.normal(
                jax.random.key(3), (2, cfg.encoder_frames, cfg.d_model),
                jnp.float32,
            )
            pre["frames"] = frames
            full["frames"] = frames
        _, cache = model.prefill(params, pre)
        for i in range(32, 36):
            lg, cache = model.decode_step(params, cache, toks[:, i:i + 1])
        lg_ref, _ = model.prefill(params, full)
        err = float(jnp.max(jnp.abs(lg - lg_ref)))
        assert err < 2e-3, (name, err)
    finally:
        layers.DTYPE = old


def test_param_counts_match_published_sizes():
    expect = {
        "qwen1.5-110b": (105e9, 118e9),
        "chameleon-34b": (32e9, 36e9),
        "gemma3-12b": (10e9, 13e9),
        "qwen2.5-3b": (2.8e9, 3.6e9),
        "gemma-2b": (2.2e9, 2.8e9),
        "zamba2-2.7b": (2.2e9, 2.9e9),
        "xlstm-350m": (0.2e9, 0.45e9),
        "whisper-small": (0.2e9, 0.45e9),
    }
    for name, (lo, hi) in expect.items():
        n = ARCHS[name].param_count()
        assert lo <= n <= hi, (name, n)


def test_moe_active_params_much_smaller():
    cfg = ARCHS["qwen2-moe-a2.7b"]
    assert cfg.active_param_count() < 0.3 * cfg.param_count()
    assert 2.2e9 < cfg.active_param_count() < 3.2e9


def test_gemma3_local_global_cache_sizes():
    """long-context: local layers allocate window-sized rolling caches."""
    cfg = SMOKES["gemma3-12b"]
    model = build_model(cfg)
    cache = model.cache_shapes(1, 4096)
    loc = cache["local"]["k"].shape
    glob = cache["global"]["k"].shape
    assert loc[3] <= cfg.sliding_window + 32
    assert glob[2] >= 4096
