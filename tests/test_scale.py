"""Sharded serving core + deterministic trace harness (ISSUE 9).

Four concerns, one file:

* **one-shard differential** — a ``ShardedSchedulingService`` with one
  shard in immediate mode is a transparent proxy: bit-identical plan
  signature, stats and deadline report to driving ``SchedulingService``
  directly, on single-device and cluster pools, with deadlines,
  admission, re-planning and the closed-loop fault harness on top;
* **fast-admission soundness** — the deferred fast path's envelope bound
  dominates the exact running-work lower bound at every submit instant,
  so it never admits a task the exact check would provably reject; no
  placement ever begins before its submit decision; quiescing yields
  valid per-shard schedules (deterministic seeded loops here, the
  generative version lives in ``test_scale_property.py``);
* **trace determinism** — ``repro.core.traces`` streams are a pure
  function of ``(seed, mix, n)``: byte-identical digests across
  generations, distinct seeds/mixes differ, and replaying a trace
  through ``run_with_faults`` reproduces the fixed-seed fault matrix
  results event-for-event;
* **EDF flush ordering** — ``SchedulerConfig(edf=True)`` reorders
  deadline carriers within each flush chain and never worsens (and in
  aggregate strictly improves) the miss rate on a bursty poor-scaling
  deadline stream.

The ``soak``-marked test at the bottom streams 50k trace tasks through a
multi-shard deferred service; it is excluded from the default run
(``addopts = -m "not soak"``) and exercised by the CI bench-smoke job.
"""

import numpy as np
import pytest

from repro.core.cluster import cluster
from repro.core.device_spec import A30, A100
from repro.core.faults import (
    FaultInjector,
    FaultSpec,
    RetryPolicy,
    run_with_faults,
)
from repro.core.online import completion_floor
from repro.core.policy import SchedulerConfig, get_policy
from repro.core.problem import Task
from repro.core.service import SchedulingService
from repro.core.sharded import ShardedSchedulingService
from repro.core.synth import generate_cluster_tasks, generate_tasks, workload
from repro.core.traces import TraceSpec, trace_digest, trace_events

from invariants import (
    assert_fault_invariants,
    assert_valid_schedule,
    shard_floors,
)

EPS = 1e-9


def _plan_signature(svc):
    return sorted(
        (it.task.id, it.node.key, it.begin, it.end, it.size)
        for it in svc.combined_schedule().items
    )


def _cfg(**kw):
    base = dict(max_wait_s=5.0, max_batch=8, min_batch=2, replan=True)
    base.update(kw)
    return SchedulerConfig(**base)


def _stream(pool, n, seed, gap=1.2, slack=120.0):
    if hasattr(pool, "devices"):
        tasks = generate_cluster_tasks(n, pool, "mixed", "wide", seed=seed)
    else:
        tasks = generate_tasks(n, pool, workload("mixed", "wide", pool),
                               seed=seed)
    rng = np.random.default_rng(seed + 1000)
    arrivals = np.cumsum(rng.exponential(gap, size=n))
    return [(float(a), t, float(a) + slack) for a, t in zip(arrivals, tasks)]


def _drive(svc, stream, deadlines=True):
    for a, t, dl in stream:
        svc.submit(t, arrival=a, deadline=dl if deadlines else None)
    svc.drain()
    return svc


# --- one-shard differential: the facade is a transparent proxy -------------

@pytest.mark.parametrize("pool_kind", ["single", "cluster"])
@pytest.mark.parametrize("admission", ["none", "reject", "demote"])
def test_one_shard_immediate_matches_sync(pool_kind, admission):
    pool = A100 if pool_kind == "single" else cluster(A100, A30, A30)
    stream = _stream(pool, 50, seed=7)
    sync = _drive(SchedulingService(
        pool=pool, policy="far", config=_cfg(admission=admission)), stream)
    sh = ShardedSchedulingService(
        pool, shards=1, policy="far", config=_cfg(admission=admission),
        defer=False)
    for a, t, dl in stream:
        assert sh.submit(t, arrival=a, deadline=dl) in (
            "queued", "placed", "demoted", "rejected")
    sh.drain()
    assert _plan_signature(sync) == _plan_signature(sh)
    assert sync.stats.submitted == sh.stats.submitted
    assert sync.stats.batches == sh.stats.batches
    assert sync.stats.rejected == sh.stats.rejected
    assert sync.stats.demoted == sh.stats.demoted
    assert sync.stats.replan_wins == sh.stats.replan_wins
    assert sync.deadline_report() == sh.deadline_report()
    assert sync.makespan == sh.makespan


def test_one_shard_immediate_matches_sync_verdicts():
    """Every intake verdict string matches the sync service's, task by
    task (admission rejections and demotions included)."""
    pool = cluster(A100, A30)
    stream = _stream(pool, 60, seed=3, slack=20.0)  # tight: forces verdicts
    sync = SchedulingService(pool=pool, policy="far",
                             config=_cfg(admission="demote"))
    sh = ShardedSchedulingService(pool, shards=1, policy="far",
                                  config=_cfg(admission="demote"),
                                  defer=False)
    for a, t, dl in stream:
        assert sync.submit(t, arrival=a, deadline=dl) \
            == sh.submit(t, arrival=a, deadline=dl)
    assert _plan_signature(_d(sync)) == _plan_signature(_d(sh))


def _d(svc):
    svc.drain()
    return svc


def test_one_shard_fault_differential():
    """The closed-loop fault harness drives the one-shard facade exactly
    like the sync service: same plan, same completions, same outages,
    same retries, same deadline report."""
    pool = cluster(A100, A30, A30)
    stream = _stream(pool, 40, seed=11, slack=150.0)

    def mkcfg():
        return _cfg(straggler_factor=2.5, retry=RetryPolicy(),
                    admission="demote")

    fs = FaultSpec(seed=3, noise_sigma=0.08, straggler_prob=0.15,
                   straggler_factor=3.0, task_fail_rate=0.002,
                   device_mtbf_s=80.0, device_repair_s=25.0,
                   domains=((1, 2),), domain_mtbf_s=90.0,
                   domain_repair_s=20.0)
    sync = SchedulingService(pool=pool, policy="far", config=mkcfg())
    rep1 = run_with_faults(sync, stream, FaultInjector(fs))
    sh = ShardedSchedulingService(pool, shards=1, policy="far",
                                  config=mkcfg(), defer=False)
    rep2 = run_with_faults(sh, stream, FaultInjector(fs))
    assert _plan_signature(sync) == _plan_signature(sh)
    assert sync.completions == sh.completions
    assert rep1.completions == rep2.completions
    assert sorted(sync.stats.failed) == sorted(sh.stats.failed)
    assert len(sync.stats.outages) == len(sh.stats.outages)
    assert len(sync.stats.retries) == len(sh.stats.retries)
    assert sync.deadline_report() == sh.deadline_report()
    assert_fault_invariants(sh)


# --- fast admission path ---------------------------------------------------

def test_fast_envelope_dominates_exact_bound():
    """At every submit instant the fast path's envelope completion bound
    is >= the exact running-work lower bound, so a fast-path admit can
    never contradict a provable exact-check reject."""
    pool = cluster(A100, A30, A30)
    stream = _stream(pool, 60, seed=5, slack=60.0)
    sh = ShardedSchedulingService(pool, shards=1, policy="far",
                                  config=_cfg(admission="reject"),
                                  defer=True)
    inner = sh.shard_services[0]
    checked = 0
    for i, (a, t, dl) in enumerate(stream):
        sh.now = max(sh.now, a)  # the instant the gate will judge at
        fast = completion_floor(
            inner._node_candidates(t), sh._envelope(0), a)
        exact = inner.completion_lower_bound(t, a)
        assert fast >= exact - EPS, (t.id, fast, exact)
        if fast <= dl + EPS:  # the gate admits: exact must agree
            assert exact <= dl + EPS
        checked += 1
        sh.submit(t, arrival=a, deadline=dl)
        if i % 7 == 6:
            sh.pump(a)
    sh.drain()
    assert checked == len(stream)


def test_fast_reject_implies_no_placement():
    """A task the gate rejects is never planned anywhere."""
    pool = cluster(A100, A30)
    stream = _stream(pool, 80, seed=9, gap=0.2, slack=4.0)  # saturating
    sh = ShardedSchedulingService(pool, shards=2, policy="far",
                                  config=_cfg(admission="reject"),
                                  defer=True)
    rejected = set()
    for i, (a, t, dl) in enumerate(stream):
        if sh.submit(t, arrival=a, deadline=dl) == "rejected":
            rejected.add(t.id)
        if i % 16 == 15:
            sh.pump(a)
    sh.drain()
    assert rejected, "stream was meant to saturate the admission gate"
    placed = {it.task.id for s in sh.shard_schedules() for it in s.items}
    assert not rejected & placed


def test_envelope_refreshes_on_completion_report():
    """Runtime completions widen the admission window immediately: a
    deadline task fast-rejected against a committed long-running
    placement is admitted once that placement's early completion lands
    via ``report(..., "completed")`` — with no ``pump()`` in between, so
    the refresh must come from the report routing itself."""
    cfg = SchedulerConfig(admission="reject", max_wait_s=0.0,
                          min_batch=1, max_batch=8)
    sh = ShardedSchedulingService(A100, shards=1, config=cfg, defer=True)
    # strong scaling -> molded to the full GPU, blocking every cell
    hog = Task(id=0, times={s: 700.0 / s for s in A100.sizes})
    assert sh.submit(hog, arrival=0.0) == "queued"
    sh.pump(0.5)  # commit the hog; it now runs until ~t=100
    probe = {s: 5.0 for s in A100.sizes}
    late = sh.submit(Task(id=1, times=probe), arrival=1.0, deadline=20.0)
    assert late == "rejected"
    assert sh.scale.fast_rejected == [1]
    # the hog finishes early; the completion report alone (no pump)
    # must drop the stale envelope so the retry clears the gate
    sh.report(0, "completed", 2.0, end=2.0)
    retry = sh.submit(Task(id=2, times=probe), arrival=2.0, deadline=20.0)
    assert retry == "queued"
    assert sh.scale.fast_rejected == [1]
    # shard selection's tail-load figure tracked the shrink too
    assert sh._tail_load[0] == 0.0


def test_no_placement_before_submit_decision():
    """Causality across the async boundary: nothing begins before its
    fast-path submit stamp, on any shard, even with stealing."""
    pool = cluster(A100, A30, A30, A100)
    stream = _stream(pool, 70, seed=13)
    sh = ShardedSchedulingService(pool, shards=2, policy="far",
                                  config=_cfg(), defer=True)
    for i, (a, t, dl) in enumerate(stream):
        sh.submit(t, arrival=a, deadline=dl)
        if i % 12 == 11:
            sh.pump(a)
    sh.drain()
    floors = shard_floors(sh)
    for inner, schedule, fl in zip(
            sh.shard_services, sh.shard_schedules(), floors):
        assert_valid_schedule(schedule, inner.spec, floors=fl)
    stamps = sh.admission_stamps()
    placed = {it.task.id: it.begin
              for s in sh.shard_schedules() for it in s.items}
    for tid, begin in placed.items():
        assert begin >= stamps[tid] - EPS


def test_quiesce_yields_valid_schedules_and_covers_stream():
    """After drain every shard's schedule passes the independent
    feasibility checker and every admitted task is placed exactly once
    across shards."""
    pool = cluster(A100, A30, A30)
    stream = _stream(pool, 60, seed=17)
    sh = ShardedSchedulingService(pool, shards=3, policy="far",
                                  config=_cfg(), defer=True)
    for i, (a, t, dl) in enumerate(stream):
        sh.submit(t, arrival=a, deadline=dl)
        if i % 20 == 19:
            sh.pump(a)
    scheds = sh.drain()
    owners = {}
    for inner, schedule in zip(sh.shard_services, scheds):
        assert_valid_schedule(schedule, inner.spec)
        for it in schedule.items:
            assert it.task.id not in owners, \
                f"task {it.task.id} placed on two shards"
            owners[it.task.id] = inner
    rep = sh.deadline_report()
    expected = {t.id for _, t, _ in stream} - set(rep["rejected"])
    assert set(owners) == expected
    assert not sh.pending


def test_sharded_run_is_deterministic():
    """Same stream + same pump cadence twice -> identical shard
    schedules, steal counts and forwarding totals."""
    pool = cluster(A100, A30, A30, A100)
    stream = _stream(pool, 80, seed=23, gap=0.6)

    def run():
        sh = ShardedSchedulingService(pool, shards=2, policy="far",
                                      config=_cfg(), defer=True)
        for i, (a, t, dl) in enumerate(stream):
            sh.submit(t, arrival=a, deadline=dl)
            if i % 9 == 8:
                sh.pump(a)
        scheds = sh.drain()
        sigs = [sorted((it.task.id, it.node.key, it.begin, it.end)
                       for it in s.items) for s in scheds]
        return sigs, sh.scale.steals, sh.scale.forwarded

    assert run() == run()


def test_stealing_moves_work_to_idle_shard():
    """A load imbalance across shard inboxes is visible to the stealer:
    submitting a burst that all lands on one shard's devices migrates
    queued work to the other at the next pump."""
    pool = cluster(A100, A100, A30, A30)
    # shard 0 = devices 0,2 (A100, A30); shard 1 = devices 1,3
    sh = ShardedSchedulingService(pool, shards=2, policy="far",
                                  config=_cfg(), defer=True)
    tasks = generate_cluster_tasks(30, pool, "mixed", "wide", seed=31)
    for t in tasks:
        sh.submit(t, arrival=0.0)
    depth_before = [len(b) for b in sh._inbox]
    sh.pump(0.0)
    # selection alone balances by work estimate; stealing must not undo
    # that, and every queued task must have been forwarded
    assert sum(depth_before) == 30
    assert sh.scale.forwarded == 30
    assert all(not b for b in sh._inbox)


def test_urgent_bypasses_inbox():
    pool = cluster(A100, A30)
    sh = ShardedSchedulingService(pool, shards=1, policy="far",
                                  config=_cfg(), defer=True)
    tasks = generate_cluster_tasks(3, pool, "mixed", "wide", seed=37)
    assert sh.submit(tasks[0], arrival=0.0, urgent=True) == "placed"
    assert sh.shard_services[0].stats.online_placements == 1
    assert not sh._inbox[0]


# --- trace harness determinism ---------------------------------------------

POOL = cluster(A100, A30, A30)


@pytest.mark.parametrize("mix", ["poisson", "bursty", "diurnal"])
def test_trace_digest_is_pure_function_of_spec(mix):
    spec = TraceSpec(seed=42, mix=mix, n=2000, rate=5.0,
                     deadline_slack=(2.0, 10.0))
    assert trace_digest(POOL, spec) == trace_digest(POOL, spec)


def test_trace_digests_differ_across_seeds_and_mixes():
    base = dict(n=1500, rate=5.0)
    digests = {
        trace_digest(POOL, TraceSpec(seed=s, mix=m, **base))
        for s in (1, 2, 3) for m in ("poisson", "bursty", "diurnal")
    }
    assert len(digests) == 9


def test_trace_stream_shape():
    spec = TraceSpec(seed=7, mix="bursty", n=3000, rate=6.0,
                     deadline_slack=(2.0, 8.0))
    last = 0.0
    ids = set()
    count = 0
    for ev in trace_events(POOL, spec):
        assert ev.arrival >= last - EPS
        assert ev.deadline is not None and ev.deadline >= ev.arrival
        assert ev.task.id not in ids
        ids.add(ev.task.id)
        last = ev.arrival
        count += 1
    assert count == spec.n


def test_trace_heavy_tail_is_capped():
    spec = TraceSpec(seed=5, mix="poisson", n=2000, rate=5.0,
                     tail_alpha=1.1, tail_cap=20.0)
    base = TraceSpec(seed=5, mix="poisson", n=2000, rate=5.0,
                     tail_alpha=1.1, tail_cap=1.0 + 1e-9)
    longest = max(max(ev.task.times.values())
                  for ev in trace_events(POOL, spec))
    longest_uncapped = max(max(ev.task.times.values())
                           for ev in trace_events(POOL, base))
    # cap ~1.0 forces factors to 1: the stretched stream must actually
    # contain stretched durations, and no factor may exceed the cap
    assert longest > longest_uncapped
    assert longest <= spec.tail_cap * longest_uncapped * (1 + 1e-6)


def test_trace_replay_reproduces_fault_matrix_results():
    """A trace replayed twice through the closed-loop fault harness is
    event-for-event identical — the trace generator composes with the
    deterministic fault injector exactly like the hand-built streams of
    ``tools/fault_matrix.py``."""
    spec = TraceSpec(seed=19, mix="bursty", n=60, rate=0.8,
                     deadline_slack=(20.0, 40.0))
    fs = FaultSpec(seed=3, noise_sigma=0.08, straggler_prob=0.15,
                   straggler_factor=3.0, task_fail_rate=0.005,
                   device_mtbf_s=80.0, device_repair_s=25.0)

    def run():
        svc = SchedulingService(
            pool=POOL, policy="far",
            config=_cfg(straggler_factor=2.5, retry=RetryPolicy()))
        stream = [(ev.arrival, ev.task, ev.deadline)
                  for ev in trace_events(POOL, spec)]
        rep = run_with_faults(svc, stream, FaultInjector(fs))
        return (sorted(rep.completions.items()), sorted(rep.failed),
                len(svc.stats.retries), len(svc.stats.outages),
                _plan_signature(svc), svc.deadline_report()["missed"])

    first, second = run(), run()
    assert first == second
    # and the fault machinery actually fired on this stream
    assert first[2] > 0 or first[3] > 0


# --- EDF within-batch flush ordering ---------------------------------------

def _edf_stream_miss(edf, seed, nburst=6, per=16, gap=40.0):
    cfg = SchedulerConfig(max_wait_s=5.0, max_batch=per, min_batch=2,
                          replan=False, edf=edf)
    w = workload("poor", "wide", A100)
    tasks = generate_tasks(nburst * per, A100, w, seed=seed)
    rng = np.random.default_rng(seed + 100)
    svc = SchedulingService(A100, policy="far", config=cfg)
    i = 0
    for b in range(nburst):
        t0 = b * gap
        for j in range(per):
            t = tasks[i]
            i += 1
            a = t0 + j * 1e-3
            slack = 1.5 if rng.random() < 0.5 else 40.0
            dl = a + slack * min(t.times.values()) + 5.0
            svc.submit(t, arrival=a, deadline=dl)
    svc.drain()
    return len(svc.deadline_report()["missed"]), svc


def test_edf_never_worse_and_strictly_better_in_aggregate():
    """EDF reorders deadline carriers within each flush chain: on bursty
    poor-scaling deadline streams it never misses more than FIFO on any
    pinned seed and strictly fewer in aggregate."""
    total_fifo = total_edf = 0
    for seed in (1, 2, 3, 4, 5, 6):
        fifo, _ = _edf_stream_miss(False, seed)
        edf, svc = _edf_stream_miss(True, seed)
        assert edf <= fifo, f"EDF worsened seed {seed}: {edf} > {fifo}"
        total_fifo += fifo
        total_edf += edf
        assert_valid_schedule(svc.combined_schedule(), A100)
    assert total_edf < total_fifo


def test_edf_off_is_bit_identical_to_pre_edf_behaviour():
    """The default (edf=False) must not perturb any existing stream —
    deadline bookkeeping without reordering."""
    pool = A100
    stream = _stream(pool, 40, seed=29)
    a = _drive(SchedulingService(pool, policy="far", config=_cfg()), stream)
    b = _drive(SchedulingService(
        pool, policy="far", config=_cfg(edf=False)), stream)
    assert _plan_signature(a) == _plan_signature(b)


# --- auto policy selector --------------------------------------------------

def test_auto_serve_picks_far_when_dense_fixpart_when_sparse():
    cfg = SchedulerConfig()
    w = workload("mixed", "wide", A100)
    dense = generate_tasks(16, A100, w, seed=1)
    sparse = generate_tasks(3, A100, w, seed=2)
    pd = get_policy("auto-serve").plan(dense, A100, cfg)
    ps = get_policy("auto-serve").plan(sparse, A100, cfg)
    assert pd.extras["auto_choice"] == "far"
    assert ps.extras["auto_choice"] == "fix-part"
    assert pd.policy == ps.policy == "auto-serve"
    # the delegate's plan is adopted wholesale
    assert pd.makespan == get_policy("far").plan(dense, A100, cfg).makespan
    assert ps.makespan == get_policy("fix-part").plan(
        sparse, A100, cfg).makespan


def test_auto_serve_threshold_is_configurable():
    cfg = SchedulerConfig(auto_dense_batch=4)
    w = workload("mixed", "wide", A100)
    tasks = generate_tasks(4, A100, w, seed=3)
    assert get_policy("auto-serve").plan(
        tasks, A100, cfg).extras["auto_choice"] == "far"


def test_auto_serve_drives_the_service():
    pool = cluster(A100, A30)
    stream = _stream(pool, 40, seed=41)
    svc = _drive(SchedulingService(pool=pool, policy="auto-serve",
                                   config=_cfg()), stream)
    assert svc.stats.batches > 0
    assert_valid_schedule(svc.combined_schedule(), pool)


# --- soak (excluded by default; CI bench-smoke runs `-m soak`) -------------

@pytest.mark.soak
def test_soak_50k_trace_through_sharded_service():
    """Fixed-seed 50k-task smoke: the deferred sharded service sustains a
    six-figure trace without losing, duplicating or acausally placing a
    single task."""
    pool = cluster(A100, A30, A30, A100)
    spec = TraceSpec(seed=2026, mix="diurnal", n=50_000, rate=8.0)
    cfg = SchedulerConfig(max_wait_s=10.0, max_batch=64, min_batch=2,
                          replan=False)
    sh = ShardedSchedulingService(pool, shards=2, policy="auto-serve",
                                  config=cfg, defer=True)
    n = 0
    for ev in trace_events(pool, spec):
        sh.submit(ev.task, arrival=ev.arrival)
        n += 1
        if n % 256 == 0:
            sh.pump(ev.arrival)
    scheds = sh.drain()
    assert n == spec.n
    placed = set()
    for s in scheds:
        for it in s.items:
            assert it.task.id not in placed
            placed.add(it.task.id)
    assert len(placed) == spec.n
    stamps = sh.admission_stamps()
    for s in scheds:
        for it in s.items:
            assert it.begin >= stamps[it.task.id] - EPS
    # queue depth stayed bounded at the pump cadence
    assert max(d for _, d in sh.scale.queue_depths) <= 512
