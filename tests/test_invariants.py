"""The schedule-invariant harness: every registered policy on the t5/t9
workloads, every service flush, and self-tests proving the checker
actually catches each violation class."""

import dataclasses

import pytest

from invariants import InvariantViolation, assert_valid_schedule, service_floors
from repro.core import (
    A100,
    MultiBatchScheduler,
    SchedulerConfig,
    SchedulingService,
    available_policies,
    get_policy,
)
from repro.core.device_spec import InstanceNode
from repro.core.problem import Schedule, ScheduledTask
from repro.core.synth import generate_tasks, workload

CFG = SchedulerConfig()


def _t5_tasks(seed=0, n=15):
    return generate_tasks(n, A100, workload("mixed", "wide", A100), seed=seed)


def _t9_batches(n_batches=3, n=8):
    return [
        generate_tasks(n, A100, workload("mixed", "wide", A100),
                       seed=s, id_offset=10_000 * s)
        for s in range(n_batches)
    ]


# -- every registered policy passes the harness -----------------------------

@pytest.mark.parametrize("name", available_policies())
def test_policy_output_passes_invariants_t5(name):
    tasks = _t5_tasks(seed=1)
    plan = get_policy(name).plan(tasks, A100, CFG)
    if name == "lower-bound":  # schedule-less denominator policy
        assert_valid_schedule(plan.schedule, A100)
        return
    assert_valid_schedule(plan.schedule, A100, tasks=tasks)


@pytest.mark.parametrize(
    "name", [n for n in available_policies() if n != "lower-bound"]
)
def test_policy_through_multibatch_passes_invariants_t9(name):
    batches = _t9_batches()
    mb = MultiBatchScheduler(A100, policy=name, config=CFG)
    for b in batches:
        mb.add_batch(b)
    assert_valid_schedule(
        mb.combined_schedule(), A100, tasks=[t for b in batches for t in b]
    )


# -- the serving facade passes it on every flush ----------------------------

@pytest.mark.parametrize("replan", [False, True])
def test_service_flushes_pass_invariants(replan):
    tasks = _t5_tasks(seed=7, n=14)
    svc = SchedulingService(
        A100,
        config=SchedulerConfig(max_wait_s=3.0, max_batch=5, replan=replan),
    )
    arrival = 0.0
    for i, t in enumerate(tasks):
        arrival += 0.5 if i % 5 else 25.0
        svc.submit(t, arrival=arrival, deadline=arrival + 500.0)
        # the partially-committed timeline is valid after every flush, on
        # the primary chain and on the reporting surface alike
        assert_valid_schedule(svc.mb.combined_schedule(), A100)
        assert_valid_schedule(svc.combined_schedule(), A100)
    combined = svc.drain()
    assert_valid_schedule(
        combined, A100, tasks=tasks, floors=service_floors(svc)
    )


# -- self-tests: the checker catches what it claims to ----------------------

def _valid_schedule():
    tasks = _t5_tasks(seed=3, n=8)
    plan = get_policy("far").plan(tasks, A100, CFG)
    return plan.schedule, tasks


def test_checker_accepts_far_and_rejects_duplicate():
    sched, tasks = _valid_schedule()
    assert_valid_schedule(sched, A100, tasks=tasks)
    tampered = Schedule(
        spec=sched.spec,
        items=sched.items + [sched.items[0]],
        reconfigs=sched.reconfigs,
    )
    with pytest.raises(InvariantViolation, match="more than once"):
        assert_valid_schedule(tampered, A100)


def test_checker_rejects_slice_overlap():
    sched, _ = _valid_schedule()
    it = max(sched.items, key=lambda it: it.begin)
    shifted = dataclasses.replace(it, begin=0.0)
    others = [o for o in sched.items if o is not it]
    with pytest.raises(InvariantViolation, match="overlap"):
        assert_valid_schedule(
            Schedule(spec=sched.spec, items=others + [shifted],
                     reconfigs=sched.reconfigs),
            A100,
        )


def test_checker_rejects_foreign_node_and_bad_molding():
    sched, _ = _valid_schedule()
    alien = InstanceNode(tree=9, start=0, size=1, footprint=1)
    it = sched.items[0]
    with pytest.raises(InvariantViolation, match="repartitioning tree"):
        assert_valid_schedule(
            Schedule(spec=sched.spec,
                     items=[dataclasses.replace(it, node=alien)],
                     reconfigs=[]),
            A100,
        )
    node7 = next(n for n in A100.nodes if n.size == 7)
    bad = ScheduledTask(task=it.task, node=node7, begin=0.0, size=1)
    with pytest.raises(InvariantViolation, match="molded"):
        assert_valid_schedule(
            Schedule(spec=sched.spec, items=[bad], reconfigs=[]), A100
        )


def test_checker_rejects_floor_violation_and_batch_mismatch():
    sched, tasks = _valid_schedule()
    first = min(sched.items, key=lambda it: it.begin)
    with pytest.raises(InvariantViolation, match="causal floor"):
        assert_valid_schedule(
            sched, A100, floors={first.task.id: first.begin + 1.0}
        )
    with pytest.raises(InvariantViolation, match="batch ids"):
        assert_valid_schedule(sched, A100, tasks=tasks[:-1])


def test_checker_cross_checks_validate_schedule():
    """The harness and problem.validate_schedule agree on the good case —
    two independent implementations of the same model."""
    from repro.core import validate_schedule

    sched, tasks = _valid_schedule()
    validate_schedule(sched, tasks)
    assert_valid_schedule(sched, A100, tasks=tasks)
