"""Online scheduler (paper §7 future work): feasibility + sanity."""

from repro.core.device_spec import A100, TPU_POD_256
from repro.core.far import schedule_batch
from repro.core.online import OnlineScheduler
from repro.core.problem import validate_schedule
from repro.core.synth import generate_tasks, workload


def test_online_always_feasible_and_bounded():
    for spec in (A100, TPU_POD_256):
        for seed in range(3):
            tasks = generate_tasks(
                12, spec, workload("mixed", "wide", spec), seed=seed
            )
            online = OnlineScheduler(spec)
            for t in tasks:
                online.submit(t)
            sched = online.schedule()
            validate_schedule(sched, tasks)
            far = schedule_batch(tasks, spec)
            assert sched.makespan >= far.makespan - 1e-6  # offline wins
            assert sched.makespan <= 5 * far.makespan     # but sanely so


def test_online_molds_to_different_sizes():
    tasks = generate_tasks(
        10, A100, workload("mixed", "wide", A100), seed=1
    )
    online = OnlineScheduler(A100)
    sizes = {online.submit(t).size for t in tasks}
    assert len(sizes) > 1  # actually exercises moldability
