"""Online scheduler (paper §7 future work): feasibility + sanity."""

from repro.core.device_spec import A100, TPU_POD_256
from repro.core.far import schedule_batch
from repro.core.multibatch import MultiBatchScheduler
from repro.core.online import OnlineScheduler
from repro.core.problem import validate_schedule
from repro.core.repartition import replay
from repro.core.synth import generate_tasks, workload


def test_online_always_feasible_and_bounded():
    for spec in (A100, TPU_POD_256):
        for seed in range(3):
            tasks = generate_tasks(
                12, spec, workload("mixed", "wide", spec), seed=seed
            )
            online = OnlineScheduler(spec)
            for t in tasks:
                online.submit(t)
            sched = online.schedule()
            validate_schedule(sched, tasks)
            far = schedule_batch(tasks, spec)
            assert sched.makespan >= far.makespan - 1e-6  # offline wins
            assert sched.makespan <= 5 * far.makespan     # but sanely so


def test_online_molds_to_different_sizes():
    tasks = generate_tasks(
        10, A100, workload("mixed", "wide", A100), seed=1
    )
    online = OnlineScheduler(A100)
    sizes = {online.submit(t).size for t in tasks}
    assert len(sizes) > 1  # actually exercises moldability


def test_online_persistent_engine_matches_cold_replay():
    """makespan/schedule are served from one long-lived engine; a cold
    replay of the committed assignment is the oracle after every submit
    (the timing-engine replay-equivalence contract, bit-for-bit)."""
    tasks = generate_tasks(
        10, A100, workload("mixed", "wide", A100), seed=2
    )
    online = OnlineScheduler(A100)
    for t in tasks:
        online.submit(t)
        assert online.makespan == replay(online.assignment).makespan
        cold = replay(online.assignment)
        hot = online.schedule()
        assert [(it.task.id, it.begin, it.node.key) for it in hot.items] == \
            [(it.task.id, it.begin, it.node.key) for it in cold.items]


def test_online_with_tail_context_extends_committed_schedule():
    """Seeded with a committed tail, arrivals land after the released
    slices and the combined (batch + online) schedule stays feasible."""
    batch = generate_tasks(8, A100, workload("mixed", "wide", A100), seed=3)
    mb = MultiBatchScheduler(A100, mode="trivial")
    mb.add_batch(batch)
    extra = generate_tasks(
        4, A100, workload("mixed", "wide", A100), seed=4, id_offset=1_000
    )
    online = OnlineScheduler(
        A100, release=mb.tail.release, alive=mb.tail.alive
    )
    for t in extra:
        online.submit(t)
    mb.adopt_segment(online.schedule())
    validate_schedule(
        mb.combined_schedule(), batch + extra, check_reconfig=False
    )
