"""Runtime: cost model, executor, fault tolerance, elastic rescheduling."""

import itertools

import pytest

from repro.configs import ARCHS
from repro.core.costmodel import Job, job_time, job_to_task, step_time
from repro.core.device_spec import TPU_POD_256
from repro.core.problem import validate_schedule
from repro.models.config import SHAPES
from repro.runtime import ClusterManager, Fault, SimExecutor, Slowdown


def _jobs(mgr, n=10, steps=50):
    shapes = [SHAPES["train_4k"], SHAPES["decode_32k"],
              SHAPES["prefill_32k"]]
    for cfg, sh in itertools.islice(
        itertools.product(ARCHS.values(), shapes), n
    ):
        mgr.submit(mgr.new_job(cfg, sh, steps=steps))


def test_cost_model_times_monotone_non_increasing():
    for cfg in ARCHS.values():
        for sh in ("train_4k", "prefill_32k", "decode_32k"):
            job = Job(0, cfg, SHAPES[sh], steps=10)
            task = job_to_task(job, TPU_POD_256)
            sizes = sorted(task.times)
            assert task.check_time_monotone(), (cfg.name, sh)
            assert all(task.times[s] > 0 for s in sizes)


def test_cost_model_spill_makes_work_non_monotone():
    """qwen1.5-110b training cannot fit 32 chips -> super-linear speedup
    regime (the TPU analogue of paper §2.4)."""
    cfg = ARCHS["qwen1.5-110b"]
    job = Job(0, cfg, SHAPES["train_4k"], steps=10)
    t = job_to_task(job, TPU_POD_256)
    works = {s: s * t.times[s] for s in t.times}
    assert min(works, key=works.get) > 1  # min-work NOT at one slice


def test_executor_zero_drift_without_faults():
    mgr = ClusterManager(TPU_POD_256)
    _jobs(mgr, 8)
    rec = mgr.run_batch()
    assert rec.result.drift == pytest.approx(0.0, abs=1e-9)
    assert len(rec.result.finished) == 8
    validate_schedule(rec.schedule, check_reconfig=False)


def test_executor_detects_stragglers():
    mgr = ClusterManager(TPU_POD_256, straggle_tol=0.05)
    _jobs(mgr, 8)
    rec = mgr.run_batch(slowdowns=[Slowdown(0, 0, 1.2)])
    assert rec.result.stragglers  # something ran on slice 0 and drifted
    assert rec.result.makespan >= rec.result.sim_makespan


def test_fault_kills_and_restarts_from_checkpoint():
    mgr = ClusterManager(TPU_POD_256)
    _jobs(mgr, 10, steps=100)
    first = mgr.run_batch()
    mid = first.result.makespan  # schedule a fresh batch with a mid-fault
    _jobs(mgr, 10, steps=100)
    rec = mgr.run_batch(faults=[Fault(mid + 50.0, 0, 3)])
    assert rec.result.killed
    # killed jobs requeued with remaining steps <= original
    restarts = [j for j in mgr.queue if "restart" in (j.name or "")]
    assert len(restarts) == len(rec.result.killed)
    for j in restarts:
        assert 0 < j.steps <= 100
    # degraded spec excludes the dead slice
    assert mgr.spec.n_slices == 7
    # next batch completes on the degraded pod
    rec2 = mgr.run_batch()
    assert len(rec2.result.finished) == len(rec2.jobs)
    validate_schedule(rec2.schedule, check_reconfig=False)


def test_utilization_reported():
    mgr = ClusterManager(TPU_POD_256)
    _jobs(mgr, 12)
    mgr.run_batch()
    u = mgr.utilization()
    assert 0.2 < u <= 1.0


def test_job_time_decreases_with_slices():
    cfg = ARCHS["gemma3-12b"]
    job = Job(0, cfg, SHAPES["train_4k"], steps=100)
    times = [job_time(job, s) for s in (1, 2, 4, 8)]
    assert times == sorted(times, reverse=True)


def test_multibatch_cluster_keeps_validating():
    mgr = ClusterManager(TPU_POD_256, concat_mode="auto")
    for _ in range(3):
        _jobs(mgr, 6)
        mgr.run_batch()
    combined_items = [
        it for r in mgr.history for it in r.schedule.items
    ]
    assert len(combined_items) == 18
    # every pair of overlapping-footprint items is time-disjoint
    for i, a in enumerate(combined_items):
        ca = {(a.node.tree, s) for s in a.node.blocked}
        for b in combined_items[i + 1:]:
            cb = {(b.node.tree, s) for s in b.node.blocked}
            if ca & cb:
                assert a.end <= b.begin + 1e-6 or b.end <= a.begin + 1e-6
