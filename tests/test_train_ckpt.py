"""End-to-end training, checkpoint/restart determinism, data pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ckpt as ckpt_lib
from repro.data import SyntheticTokens, TokenBatchIterator
from repro.launch.train import train


def test_loss_decreases_on_tiny_model(tmp_path):
    out = train("gemma-2b", steps=30, batch=8, seq=64, smoke=True,
                log_fn=lambda *_: None)
    assert out["last_loss"] < out["first_loss"] - 0.2


def test_checkpoint_restart_is_bit_deterministic(tmp_path):
    d1 = str(tmp_path / "a")
    kw = dict(steps=10, batch=4, seq=32, smoke=True, log_fn=lambda *_: None)
    full = train("qwen2.5-3b", **kw)

    d2 = str(tmp_path / "b")
    # interrupted leg: same LR-schedule horizon as the full run
    train("qwen2.5-3b", ckpt_dir=d2, ckpt_every=5, total_steps=10,
          **{**kw, "steps": 5})
    resumed = train("qwen2.5-3b", ckpt_dir=d2, ckpt_every=5, **kw)
    assert resumed["last_loss"] == pytest.approx(full["last_loss"], rel=1e-6)


def test_checkpoint_atomicity_and_gc(tmp_path):
    d = str(tmp_path)
    state = {"w": jnp.arange(8, dtype=jnp.float32)}
    for step in (1, 2, 3, 4):
        ckpt_lib.save_checkpoint(d, step, state, keep=2)
    assert ckpt_lib.latest_step(d) == 4
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(steps) == 2  # gc keeps 2
    # a stale tmp dir never counts as a checkpoint
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    assert ckpt_lib.latest_step(d) == 4
    restored, meta = ckpt_lib.restore_checkpoint(d, state)
    assert meta["step"] == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(8, dtype=np.float32))


def test_restore_into_different_dtype(tmp_path):
    d = str(tmp_path)
    ckpt_lib.save_checkpoint(d, 1, {"w": jnp.ones((4,), jnp.float32)})
    like = {"w": jax.ShapeDtypeStruct((4,), jnp.bfloat16)}
    restored, _ = ckpt_lib.restore_checkpoint(d, like)
    assert restored["w"].dtype == jnp.bfloat16


def test_data_pipeline_deterministic_and_host_sharded():
    src = SyntheticTokens(vocab_size=97, seq_len=16, global_batch=8, seed=3)
    a = src.batch(5)
    b = src.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])
    # host shards are distinct and sized global/hosts
    h0 = src.batch(5, host_id=0, host_count=2)
    h1 = src.batch(5, host_id=1, host_count=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_prefetch_iterator_resumes_at_index():
    src = SyntheticTokens(vocab_size=31, seq_len=8, global_batch=2, seed=0)
    it = TokenBatchIterator(src, start_index=7, prefetch=1)
    first = next(it)
    it.close()
    np.testing.assert_array_equal(first["tokens"], src.batch(7)["tokens"])
