"""Hypothesis property tests for the sharded fast admission path.

Generative counterparts of the deterministic loops in
``tests/test_scale.py`` — random streams, random pump cadence, random
shard counts:

1. **envelope dominance / admission soundness** — at every submit
   instant the fast path's committed-work envelope bound is >= the
   owning shard's exact running-work lower bound, so the fast gate never
   admits a task the exact completion-bound check would provably reject;
2. **causality** — no placement ever begins before the fast-path submit
   decision that accepted it (planning is deferred, the stamp is not);
3. **quiescence** — draining after an arbitrary submit/pump interleaving
   yields per-shard schedules that pass the independent feasibility
   checker, with every admitted task placed exactly once pool-wide.
"""

import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from invariants import assert_valid_schedule, shard_floors
from repro.core import SchedulerConfig, Task, cluster
from repro.core.device_spec import A30, A100
from repro.core.online import completion_floor
from repro.core.sharded import ShardedSchedulingService

EPS = 1e-9
POOL = cluster(A100, A30, A30, A100)


@st.composite
def sharded_streams(draw, max_tasks=14):
    """A random stream over the 4-device pool: per-task monotone-in-size
    profiles on the sizes A100 and A30 share, bursty-or-sparse gaps,
    optional deadlines, plus a shard count and a random pump schedule."""
    n = draw(st.integers(4, max_tasks))
    sizes = sorted(set(A100.sizes) & set(A30.sizes))
    tasks, arrivals, deadlines = [], [], {}
    now = 0.0
    for i in range(n):
        t1 = draw(st.floats(0.5, 40.0, allow_nan=False))
        times, cur = {}, t1
        for s in sizes:
            if s != sizes[0]:
                cur = cur * draw(st.floats(0.3, 1.0))
            times[s] = cur
        tasks.append(Task(id=i, times=times))
        now += draw(st.sampled_from([0.0, 0.2, 1.0, 5.0, 40.0]))
        arrivals.append(now)
        slack = draw(st.sampled_from([None, 0.5, 3.0, 50.0, 1e6]))
        if slack is not None:
            deadlines[i] = now + slack
    shards = draw(st.sampled_from([1, 2, 4]))
    budget = draw(st.sampled_from([1.0, 4.0, 15.0]))
    max_batch = draw(st.sampled_from([3, 6, 32]))
    pump_after = draw(st.sets(st.integers(0, n - 1)))
    return tasks, arrivals, deadlines, shards, budget, max_batch, pump_after


def _service(stream, admission):
    tasks, arrivals, deadlines, shards, budget, max_batch, _ = stream
    return ShardedSchedulingService(
        POOL, shards=shards, policy="far",
        config=SchedulerConfig(max_wait_s=budget, max_batch=max_batch,
                               admission=admission),
        defer=True)


@settings(max_examples=25, deadline=None)
@given(sharded_streams())
def test_fast_gate_never_contradicts_exact_check(stream):
    tasks, arrivals, deadlines, shards, budget, max_batch, pumps = stream
    sh = _service(stream, admission="reject")
    for i, (t, a) in enumerate(zip(tasks, arrivals)):
        sh.now = max(sh.now, a)
        shard = sh._select_shard(t)
        if shard is not None:
            inner = sh.shard_services[shard]
            fast = completion_floor(
                inner._node_candidates(t), sh._envelope(shard), a)
            exact = inner.completion_lower_bound(t, a)
            # dominance: the envelope bound can only be the stricter one
            assert fast >= exact - EPS
            dl = deadlines.get(t.id)
            if dl is not None and fast <= dl + EPS:
                # the gate admits -> the exact check must admit too
                assert exact <= dl + EPS
        sh.submit(t, arrival=a, deadline=deadlines.get(t.id))
        if i in pumps:
            sh.pump(a)
    sh.drain()


@settings(max_examples=25, deadline=None)
@given(sharded_streams())
def test_no_placement_before_submit_decision(stream):
    tasks, arrivals, deadlines, shards, budget, max_batch, pumps = stream
    sh = _service(stream, admission="none")
    for i, (t, a) in enumerate(zip(tasks, arrivals)):
        sh.submit(t, arrival=a, deadline=deadlines.get(t.id))
        if i in pumps:
            sh.pump(a)
    sh.drain()
    floors = shard_floors(sh)
    for inner, schedule, fl in zip(
            sh.shard_services, sh.shard_schedules(), floors):
        assert_valid_schedule(schedule, inner.spec, floors=fl)
    stamps = sh.admission_stamps()
    for schedule in sh.shard_schedules():
        for it in schedule.items:
            assert it.begin >= stamps[it.task.id] - EPS


@settings(max_examples=25, deadline=None)
@given(sharded_streams())
def test_quiescing_yields_valid_covering_schedules(stream):
    tasks, arrivals, deadlines, shards, budget, max_batch, pumps = stream
    sh = _service(stream, admission="reject")
    for i, (t, a) in enumerate(zip(tasks, arrivals)):
        sh.submit(t, arrival=a, deadline=deadlines.get(t.id))
        if i in pumps:
            sh.pump(a)
    sh.drain()
    placed = {}
    for inner, schedule in zip(sh.shard_services, sh.shard_schedules()):
        assert_valid_schedule(schedule, inner.spec)
        for it in schedule.items:
            assert it.task.id not in placed
            placed[it.task.id] = it
    rejected = set(sh.deadline_report()["rejected"])
    assert set(placed) == {t.id for t in tasks} - rejected
    assert not sh.pending
