"""HLO analyzer: loop-aware FLOP counting matches analytic counts."""

import subprocess
import sys

from repro.launch.hlo_analysis import (
    _split_computations,
    _symbol_table,
    _trip_count,
    analyze,
)

_PROBE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import sys; sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_analysis import analyze

mesh = jax.make_mesh((4, 4), ("data", "model"))
D, F, L = 256, 512, 8

def loss(params, x):
    def body(c, p):
        h = jnp.dot(c, p["w1"], preferred_element_type=jnp.float32)
        h = h.astype(jnp.bfloat16)
        c = jnp.dot(jax.nn.relu(h), p["w2"],
                    preferred_element_type=jnp.float32).astype(jnp.bfloat16)
        c = jax.lax.with_sharding_constraint(c, P("data", None, "model"))
        return c, None
    x, _ = jax.lax.scan(body, x, params)
    return jnp.sum(x.astype(jnp.float32))

params = {"w1": jax.ShapeDtypeStruct((L, D, F), jnp.bfloat16),
          "w2": jax.ShapeDtypeStruct((L, F, D), jnp.bfloat16)}
x = jax.ShapeDtypeStruct((16, 64, D), jnp.bfloat16)
psh = {"w1": NamedSharding(mesh, P(None, None, "model")),
       "w2": NamedSharding(mesh, P(None, "model", None))}
xsh = NamedSharding(mesh, P("data", None, None))
with mesh:
    comp = jax.jit(jax.grad(loss),
                   in_shardings=(psh, xsh)).lower(params, x).compile()
res = analyze(comp.as_text())
analytic = 2 * 4 * 64 * 256 * 128 * 2 * 8 * 3   # per-device fwd+bwd
ratio = res["flops_per_device"] / analytic
assert 0.95 < ratio < 1.3, ratio
assert res["collective_total"] > 0
print("ANALYZE_OK", ratio)
"""


def test_loop_aware_flops_match_analytic():
    out = subprocess.run(
        [sys.executable, "-c", _PROBE, "src"],
        capture_output=True, text=True, timeout=600, cwd=".",
    )
    assert "ANALYZE_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]


def test_parser_units():
    hlo = """
HloModule test

%fused_computation (param_0: f32[8,16]) -> f32[8,16] {
  %param_0 = f32[8,16]{1,0} parameter(0)
  ROOT %e = f32[8,16]{1,0} exponential(%param_0)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %d = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8,16]) tuple(%i, %d)
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %f = f32[8,16]{1,0} fusion(%a), kind=kLoop, calls=%fused_computation
  %init = (s32[], f32[8,16]) tuple()
  %w = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
  ROOT %g = f32[8,16]{1,0} get-tuple-element(%w), index=1
}
"""
    comps = _split_computations(hlo)
    assert set(comps) >= {"fused_computation", "cond", "body", "main"}
    assert _trip_count(comps["cond"]) == 5
    res = analyze(hlo)
    # dot flops: 2*8*16*16 = 4096 per iteration, times 5 trips
    assert res["flops_per_device"] == 4096 * 5
