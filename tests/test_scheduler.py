"""FAR behaviour: feasibility, bounds, phase contributions, baselines."""

import pytest

from repro.core import (
    A30, A100, TPU_POD_256,
    SchedulerConfig, Task, area_lower_bound, rho, schedule_batch,
    validate_schedule,
)
from repro.core.allocations import allocation_family, first_allocation
from repro.core.baselines import (
    fix_part, fix_part_best, miso_opt, partition_of_ones, partition_whole,
)
from repro.core.bounds import approximation_factor, theorem1_rigid_bound
from repro.core.repartition import list_schedule_allocation, replay
from repro.core.rodinia import TABLE3_KERNELS, rodinia_tasks
from repro.core.synth import generate_tasks, workload


def test_far_rodinia_valid_and_close_to_paper():
    tasks = rodinia_tasks(A100)
    res = schedule_batch(tasks, A100)
    validate_schedule(res.schedule, tasks)
    r = rho(res, tasks)
    # paper reports 1.22 on their profiles; ours is a digitised fixture
    assert r < 1.5, r
    assert r < approximation_factor(A100)


def test_far_a30_table3_batch():
    tasks = rodinia_tasks(A30, TABLE3_KERNELS)
    res = schedule_batch(tasks, A30)
    validate_schedule(res.schedule, tasks)
    assert rho(res, tasks) < 1.75


@pytest.mark.parametrize("spec", [A30, A100, TPU_POD_256])
@pytest.mark.parametrize("scaling,times", [("mixed", "wide"),
                                           ("poor", "narrow"),
                                           ("good", "wide")])
def test_far_synthetic_validity_and_factor(spec, scaling, times):
    for seed in range(3):
        tasks = generate_tasks(18, spec, workload(scaling, times, spec),
                               seed=seed)
        res = schedule_batch(tasks, spec)
        validate_schedule(res.schedule, tasks)
        # certified approximation factor versus the area lower bound
        # (reconfig excluded in the proof; it is tiny vs these durations)
        factor = approximation_factor(spec)
        lb = area_lower_bound(tasks, spec)
        hmax = max(min(t.times.values()) for t in tasks)
        assert res.makespan <= factor * max(lb, hmax) + 5.0


def test_phase2_respects_theorem1_bound():
    """List-scheduling bound (§5) holds for every allocation's schedule."""
    for spec in (A30, A100):
        tasks = generate_tasks(
            15, spec, workload("mixed", "wide", spec), seed=7
        )
        for alloc in allocation_family(tasks, spec):
            assign = list_schedule_allocation(tasks, alloc, spec)
            sched = replay(assign, include_reconfig=False)
            assert sched.makespan <= theorem1_rigid_bound(sched) + 1e-6


def test_allocation_family_monotonicity():
    spec = A100
    tasks = generate_tasks(12, spec, workload("mixed", "wide", spec), seed=3)
    fam = allocation_family(tasks, spec)
    assert fam[0] == first_allocation(tasks, spec)
    prev_area, prev_hmax = -1.0, float("inf")
    for alloc in fam:
        area = sum(s * t.times[s] for t, s in zip(tasks, alloc))
        hmax = max(t.times[s] for t, s in zip(tasks, alloc))
        assert area >= prev_area - 1e-9      # work non-decreasing
        assert hmax <= prev_hmax + 1e-9      # longest task non-increasing
        prev_area, prev_hmax = area, hmax
    # family ends when the longest task is maximal
    last = fam[-1]
    j = max(range(len(tasks)), key=lambda i: tasks[i].times[last[i]])
    assert last[j] == max(spec.sizes)


def test_refinement_never_hurts():
    spec = A100
    for seed in range(5):
        tasks = generate_tasks(20, spec, workload("mixed", "narrow", spec),
                               seed=seed)
        r_no = schedule_batch(tasks, spec, SchedulerConfig(refine=False))
        r_yes = schedule_batch(tasks, spec, SchedulerConfig(refine=True))
        assert r_yes.makespan <= r_no.makespan + 1e-9
        validate_schedule(r_yes.schedule, tasks)


def test_pruning_does_not_change_result():
    spec = A100
    tasks = generate_tasks(14, spec, workload("good", "wide", spec), seed=11)
    a = schedule_batch(tasks, spec, SchedulerConfig(prune=True))
    b = schedule_batch(tasks, spec, SchedulerConfig(prune=False))
    assert abs(a.makespan - b.makespan) < 1e-9
    assert a.evaluated <= b.evaluated


def test_baselines_valid_and_far_wins_on_average():
    spec = A100
    wins = 0
    for seed in range(6):
        tasks = generate_tasks(15, spec, workload("mixed", "wide", spec),
                               seed=seed)
        far = schedule_batch(tasks, spec)
        m = miso_opt(tasks, spec)
        validate_schedule(m, tasks, check_reconfig=False)
        f1 = fix_part(tasks, spec, partition_of_ones(spec))
        validate_schedule(f1, tasks, check_reconfig=False)
        fb, _ = fix_part_best(tasks, spec)
        fw = fix_part(tasks, spec, partition_whole(spec))
        assert far.makespan <= min(m.makespan, f1.makespan, fw.makespan) * 1.2
        if far.makespan <= fb.makespan + 1e-9:
            wins += 1
    assert wins >= 4  # FAR beats even FixPartBest almost always


def test_missing_profile_raises():
    t = Task(0, {1: 3.0, 2: 2.0})  # no size-4/3/7 times
    with pytest.raises(ValueError):
        schedule_batch([t], A100)


def test_empty_batch():
    res = schedule_batch([], A100)
    assert res.makespan == 0.0
