"""Equivalence and unit tests for the phase-2 family evaluators.

The hard contract: ``evaluator="vectorized"`` and ``evaluator="sequential"``
pick the **bit-identical** winner — index, allocation, assignment,
pre-refine makespan, evaluated count and final schedule — on any workload,
spec, and prune setting.  These tests exercise it deterministically
(seeded random floats plus integer-duration workloads, which are dense in
exact time ties and therefore stress the ``(time, seq)`` tie-breaking);
the hypothesis suite in ``test_scheduler_property.py`` adds randomized
coverage.
"""

import numpy as np
import pytest

from repro.core.device_spec import A30, A100, H100, TPU_POD_256
from repro.core.family_eval import (
    AUTO_MIN_FAMILY,
    AUTO_MIN_TASKS,
    EVALUATORS,
    HAVE_JAX,
    get_evaluator,
    family_areas,
    resolve_evaluator,
)
from repro.core.far import schedule_batch
from repro.core.allocations import allocation_family_deltas
from repro.core.policy import SchedulerConfig
from repro.core.problem import Task
from repro.core.repartition import LPTGroups, size_sorted_orders
from repro.core.timing import chains_makespan, chains_makespan_batch

SPECS = {"A30": A30, "A100": A100, "H100": H100, "TPU": TPU_POD_256}


def make_tasks(n, spec, seed=0, integer=False):
    """Random monotone profiles; integer mode is dense in exact ties."""
    rng = np.random.default_rng(seed)
    tasks = []
    for i in range(n):
        t1 = float(rng.integers(1, 20)) if integer \
            else float(rng.uniform(0.5, 100.0))
        times, cur = {}, t1
        for s in spec.sizes:
            if s == min(spec.sizes):
                times[s] = cur
            else:
                shrink = float(rng.integers(1, 4)) / 4.0 if integer \
                    else float(rng.uniform(0.3, 1.0))
                cur = cur * shrink
                times[s] = cur
        tasks.append(Task(id=i, times=times))
    return tasks


def assert_identical(rs, rv):
    assert rs.winner_index == rv.winner_index
    assert rs.allocation == rv.allocation
    assert rs.makespan_before_refine == rv.makespan_before_refine
    assert rs.evaluated == rv.evaluated
    assert rs.assignment.node_tasks == rv.assignment.node_tasks
    assert rs.schedule.items == rv.schedule.items
    assert rs.schedule.reconfigs == rv.schedule.reconfigs


@pytest.mark.parametrize("spec_name", sorted(SPECS))
@pytest.mark.parametrize("n", [1, 2, 7, 24, 60])
@pytest.mark.parametrize("integer", [False, True])
def test_vectorized_matches_sequential(spec_name, n, integer):
    spec = SPECS[spec_name]
    tasks = make_tasks(n, spec, seed=n * 7 + integer, integer=integer)
    for prune in (True, False):
        rs = schedule_batch(tasks, spec, SchedulerConfig(
            evaluator="sequential", prune=prune, refine=False))
        rv = schedule_batch(tasks, spec, SchedulerConfig(
            evaluator="vectorized", prune=prune, refine=False))
        assert_identical(rs, rv)


@pytest.mark.parametrize("spec_name", ["A100", "TPU"])
def test_vectorized_matches_sequential_with_refine(spec_name):
    """End-to-end (phases 2+3): identical winner implies identical final
    schedule; run once to guard the full pipeline wiring."""
    spec = SPECS[spec_name]
    tasks = make_tasks(40, spec, seed=3)
    rs = schedule_batch(tasks, spec, SchedulerConfig(evaluator="sequential"))
    rv = schedule_batch(tasks, spec, SchedulerConfig(evaluator="vectorized"))
    assert rs.makespan == rv.makespan
    assert rs.schedule.items == rv.schedule.items
    assert rs.schedule.reconfigs == rv.schedule.reconfigs


def test_synth_workload_equivalence():
    """The benchmark workloads (paper §6.3 generators) stay bit-identical
    across evaluators — the t_cost acceptance surface in miniature."""
    from repro.core.synth import generate_tasks, workload

    cfg = workload("mixed", "wide", A100)
    tasks = generate_tasks(120, A100, cfg, seed=0)
    rs = schedule_batch(tasks, A100, SchedulerConfig(evaluator="sequential"))
    rv = schedule_batch(tasks, A100, SchedulerConfig(evaluator="vectorized"))
    assert_identical(rs, rv)
    assert rs.makespan == rv.makespan


def test_chains_makespan_batch_matches_scalar():
    """The batched phase-2 scorer is bit-identical per candidate to
    chains_makespan on the same duration chains."""
    spec = A100
    rng = np.random.default_rng(5)
    cands = []
    for seed in range(6):
        tasks = make_tasks(int(rng.integers(1, 30)), spec, seed=seed)
        first, _ = allocation_family_deltas(tasks, spec)
        groups = LPTGroups(tasks, first, spec)
        a, nd = groups.schedule_with_durs()
        cands.append((a.node_tasks, nd))
    N = len(spec.nodes)
    index = {node.key: i for i, node in enumerate(spec.nodes)}
    L = max(
        (len(v) for nt, _ in cands for v in nt.values()), default=1
    )
    cd = np.zeros((len(cands), N, L))
    cl = np.zeros((len(cands), N), dtype=np.int64)
    for c, (nt, nd) in enumerate(cands):
        for key, durs in nd.items():
            cd[c, index[key], :len(durs)] = durs
            cl[c, index[key]] = len(durs)
    batch = chains_makespan_batch(spec, cd, cl)
    for c, (nt, nd) in enumerate(cands):
        assert batch[c] == chains_makespan(spec, nt, nd)


def test_chains_makespan_batch_empty():
    assert chains_makespan_batch(
        A100, np.zeros((3, len(A100.nodes), 1)),
        np.zeros((3, len(A100.nodes)), dtype=np.int64),
    ).tolist() == [0.0, 0.0, 0.0]


def test_family_areas_match_stepwise_fold():
    """The accumulated area sequence equals the one-delta-at-a-time fold
    the sequential loop would produce (same IEEE operations)."""
    spec = A100
    tasks = make_tasks(30, spec, seed=11)
    first, deltas = allocation_family_deltas(tasks, spec)
    areas = family_areas(tasks, first, deltas)
    area = sum(s * t.times[s] for t, s in zip(tasks, first))
    alloc = list(first)
    assert areas[0] == area
    for k, (j, s_new) in enumerate(deltas):
        s_old = alloc[j]
        t = tasks[j]
        area = area + (s_new * t.times[s_new] - s_old * t.times[s_old])
        alloc[j] = s_new
        assert areas[k + 1] == area


def test_size_sorted_orders_layout():
    spec = A30
    tasks = make_tasks(12, spec, seed=2)
    orders = size_sorted_orders(tasks, spec)
    for k, s in enumerate(spec.sizes):
        ref = sorted(tasks, key=lambda t: (-t.times[s], t.id))
        assert orders.ids[k].tolist() == [t.id for t in ref]
        assert orders.durs[k].tolist() == [t.times[s] for t in ref]
        # inv is the inverse permutation of order
        assert (orders.order[k][orders.inv[k]] == np.arange(len(tasks))).all()


def test_config_validation():
    with pytest.raises(ValueError, match="evaluator"):
        SchedulerConfig(evaluator="nope")
    for name in ("sequential", "incremental", "parallel", "vectorized",
                 "auto"):
        assert SchedulerConfig(evaluator=name).evaluator == name


def test_get_evaluator_unknown():
    with pytest.raises(KeyError, match="unknown family evaluator"):
        get_evaluator("nope")
    assert set(EVALUATORS) >= {
        "sequential", "incremental", "parallel", "vectorized",
    }


def test_resolve_evaluator_dispatch():
    from repro.core import fastsim

    big_n = AUTO_MIN_TASKS
    big_f = AUTO_MIN_FAMILY
    auto = SchedulerConfig(evaluator="auto")
    if fastsim.available():
        expected = "incremental"
    elif HAVE_JAX:
        expected = "vectorized"
    else:
        expected = "sequential"
    assert resolve_evaluator(auto, big_n, big_f) == expected
    # small problems stay sequential under auto
    assert resolve_evaluator(auto, 8, 4) == "sequential"
    # config-overridable floor: a tiny floor admits the compiled tier on
    # small batches, a huge floor pushes auto back to sequential
    low = SchedulerConfig(evaluator="auto", evaluator_floor=8)
    if fastsim.available():
        assert resolve_evaluator(low, 8, big_f) == "incremental"
    high = SchedulerConfig(evaluator="auto", evaluator_floor=10**9)
    assert resolve_evaluator(high, big_n, big_f) == "sequential"
    # the replay reference path always scores sequentially
    for name in ("vectorized", "incremental", "parallel"):
        ref = SchedulerConfig(evaluator=name, use_engine=False)
        assert resolve_evaluator(ref, big_n, big_f) == "sequential"
    forced = SchedulerConfig(evaluator="vectorized")
    assert resolve_evaluator(forced, 1, 1) == "vectorized"


def test_empty_batch():
    res = schedule_batch([], A100, SchedulerConfig(evaluator="vectorized"))
    assert res.makespan == 0.0 and res.family_size == 1


# -- incremental delta-replay evaluator -------------------------------------


@pytest.mark.parametrize("spec_name", sorted(SPECS))
@pytest.mark.parametrize("n", [1, 2, 7, 24, 60])
@pytest.mark.parametrize("integer", [False, True])
def test_incremental_matches_sequential(spec_name, n, integer):
    """The delta-replay evaluator inherits the full bit-identity
    contract, including the tie-dense integer workloads that stress the
    snapshot/restore divergence rules at every rank."""
    spec = SPECS[spec_name]
    tasks = make_tasks(n, spec, seed=n * 7 + integer, integer=integer)
    for prune in (True, False):
        rs = schedule_batch(tasks, spec, SchedulerConfig(
            evaluator="sequential", prune=prune, refine=False))
        ri = schedule_batch(tasks, spec, SchedulerConfig(
            evaluator="incremental", prune=prune, refine=False))
        assert_identical(rs, ri)


@pytest.mark.parametrize("spec_name", ["A100", "TPU"])
def test_incremental_python_fallback_matches(spec_name):
    """Without a C compiler the evaluator resimulates in pure Python —
    identical winners, no compiled backend involved."""
    from repro.core import fastsim

    spec = SPECS[spec_name]
    tasks = make_tasks(24, spec, seed=5)
    saved = fastsim._LOADED
    fastsim._LOADED = None  # simulate a failed build for this process
    try:
        for prune in (True, False):
            rs = schedule_batch(tasks, spec, SchedulerConfig(
                evaluator="sequential", prune=prune, refine=False))
            ri = schedule_batch(tasks, spec, SchedulerConfig(
                evaluator="incremental", prune=prune, refine=False))
            assert_identical(rs, ri)
    finally:
        fastsim._LOADED = saved


def test_incremental_with_refine():
    spec = A100
    tasks = make_tasks(40, spec, seed=3)
    rs = schedule_batch(tasks, spec, SchedulerConfig(evaluator="sequential"))
    ri = schedule_batch(tasks, spec, SchedulerConfig(evaluator="incremental"))
    assert rs.makespan == ri.makespan
    assert rs.schedule.items == ri.schedule.items
    assert rs.schedule.reconfigs == ri.schedule.reconfigs


def test_incremental_single_candidate_family():
    """A family of one (no deltas) never arms a trigger."""
    spec = A100
    tasks = [Task(id=0, times={s: 10.0 / s for s in spec.sizes})]
    first, deltas = allocation_family_deltas(tasks, spec)
    sub = deltas[:0]
    cfg = SchedulerConfig(evaluator="incremental", refine=False)
    rs = EVALUATORS["sequential"].evaluate(tasks, spec, first, sub, cfg)
    ri = EVALUATORS["incremental"].evaluate(tasks, spec, first, sub, cfg)
    assert rs.makespan == ri.makespan
    assert rs.index == ri.index == 0
    assert rs.assignment.node_tasks == ri.assignment.node_tasks


def test_incremental_pruned_to_zero_window():
    """All-ties integer durations can prune every non-first candidate;
    the winner scan must still agree after the first score."""
    spec = A30
    tasks = [Task(id=i, times={s: 8.0 for s in spec.sizes})
             for i in range(6)]  # no speedup: wider is strictly worse area
    first, deltas = allocation_family_deltas(tasks, spec)
    cfg = SchedulerConfig(evaluator="incremental", prune=True, refine=False)
    rs = EVALUATORS["sequential"].evaluate(tasks, spec, first, deltas, cfg)
    ri = EVALUATORS["incremental"].evaluate(tasks, spec, first, deltas, cfg)
    assert rs.makespan == ri.makespan
    assert rs.index == ri.index
    assert rs.evaluated == ri.evaluated
    assert rs.assignment.node_tasks == ri.assignment.node_tasks


# -- parallel family sharding -----------------------------------------------


@pytest.mark.parametrize("spec_name", ["A100", "TPU"])
@pytest.mark.parametrize("prune", [True, False])
def test_parallel_matches_sequential(spec_name, prune):
    spec = SPECS[spec_name]
    tasks = make_tasks(40, spec, seed=11)
    rs = schedule_batch(tasks, spec, SchedulerConfig(
        evaluator="sequential", prune=prune, refine=False))
    rp = schedule_batch(tasks, spec, SchedulerConfig(
        evaluator="parallel", prune=prune, refine=False,
        parallel_workers=2))
    assert_identical(rs, rp)


def test_parallel_worker_count_invariance():
    """The deterministic reduce makes the winner independent of the
    worker count (chunk boundaries move, the ordered scan does not)."""
    spec = A100
    tasks = make_tasks(30, spec, seed=2)
    results = [
        schedule_batch(tasks, spec, SchedulerConfig(
            evaluator="parallel", refine=False, parallel_workers=w))
        for w in (1, 2, 3)
    ]
    for other in results[1:]:
        assert_identical(results[0], other)
