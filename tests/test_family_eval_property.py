"""Hypothesis property suite for evaluator bit-identity at scale.

Randomized counterpart of the deterministic matrix in
``test_family_eval.py``: on arbitrary specs and workloads — including
all-ties integer durations, empty families, single-candidate families
and pruned-to-zero windows — ``incremental`` (compiled or pure-Python
fallback) and sharded-``parallel`` (2 workers) must return the exact
winner tuple ``sequential`` does: index, allocation, makespan,
``evaluated`` and assignment chains.
"""

import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.allocations import allocation_family_deltas
from repro.core.device_spec import A30, A100, H100, TPU_POD_256
from repro.core.family_eval import EVALUATORS
from repro.core.policy import SchedulerConfig
from repro.core.problem import Task

SPECS = {"A30": A30, "A100": A100, "H100": H100, "TPU": TPU_POD_256}


@st.composite
def family_cases(draw, max_tasks=24):
    """(spec, tasks, prune): monotone profiles, sometimes integer-valued
    (dense in exact duration and area ties, the divergence-rule stress),
    sometimes empty or singleton batches (degenerate families)."""
    spec = SPECS[draw(st.sampled_from(sorted(SPECS)))]
    n = draw(st.integers(0, max_tasks))
    integer = draw(st.booleans())
    tasks = []
    for i in range(n):
        if integer:
            t1 = float(draw(st.integers(1, 12)))
        else:
            t1 = draw(st.floats(0.5, 100.0, allow_nan=False))
        times, cur = {}, t1
        for s in spec.sizes:
            if s == min(spec.sizes):
                times[s] = cur
            else:
                if integer:
                    cur = cur * (float(draw(st.integers(1, 4))) / 4.0)
                else:
                    cur = cur * draw(st.floats(0.3, 1.0))
                times[s] = cur
        tasks.append(Task(id=i, times=times))
    return spec, tasks, draw(st.booleans())


def _winner_tuple(res):
    return (
        res.makespan,
        res.index,
        res.allocation,
        res.evaluated,
        res.assignment.node_tasks if res.assignment is not None else None,
    )


@settings(max_examples=60, deadline=None)
@given(family_cases())
def test_incremental_bit_identical(case):
    spec, tasks, prune = case
    first, deltas = allocation_family_deltas(tasks, spec)
    cfg = SchedulerConfig(
        evaluator="incremental", prune=prune, refine=False
    )
    rs = EVALUATORS["sequential"].evaluate(tasks, spec, first, deltas, cfg)
    ri = EVALUATORS["incremental"].evaluate(tasks, spec, first, deltas, cfg)
    assert _winner_tuple(rs) == _winner_tuple(ri)


@settings(max_examples=25, deadline=None)
@given(family_cases())
def test_incremental_python_fallback_bit_identical(case):
    from repro.core import fastsim

    spec, tasks, prune = case
    first, deltas = allocation_family_deltas(tasks, spec)
    cfg = SchedulerConfig(
        evaluator="incremental", prune=prune, refine=False
    )
    rs = EVALUATORS["sequential"].evaluate(tasks, spec, first, deltas, cfg)
    saved = fastsim._LOADED
    fastsim._LOADED = None
    try:
        ri = EVALUATORS["incremental"].evaluate(
            tasks, spec, first, deltas, cfg
        )
    finally:
        fastsim._LOADED = saved
    assert _winner_tuple(rs) == _winner_tuple(ri)


@settings(max_examples=15, deadline=None)
@given(family_cases(max_tasks=16))
def test_parallel_two_workers_bit_identical(case):
    spec, tasks, prune = case
    first, deltas = allocation_family_deltas(tasks, spec)
    cfg = SchedulerConfig(
        evaluator="parallel", prune=prune, refine=False, parallel_workers=2
    )
    rs = EVALUATORS["sequential"].evaluate(tasks, spec, first, deltas, cfg)
    rp = EVALUATORS["parallel"].evaluate(tasks, spec, first, deltas, cfg)
    assert _winner_tuple(rs) == _winner_tuple(rp)
