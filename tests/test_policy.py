"""Policy registry / SchedulerConfig surface: equivalence with the legacy
entry points (t5/t9-style workloads), feasibility of every policy's
output, and the deprecation shims."""

import dataclasses

import pytest

from repro.core import (
    A100,
    MultiBatchScheduler,
    SchedulerConfig,
    Tail,
    available_policies,
    concatenate,
    get_policy,
    multibatch_baseline,
    schedule_batch,
    validate_schedule,
)
from repro.core.baselines import (
    fix_part,
    fix_part_best,
    miso_opt,
    partition_of_ones,
    partition_whole,
)
from repro.core.online import OnlineScheduler
from repro.core.policy import LEGACY_KWARGS, PlanResult, SchedulerPolicy
from repro.core.problem import area_lower_bound
from repro.core.synth import generate_tasks, workload

CFG = SchedulerConfig()


def _t5_tasks(seed=0, n=15):
    return generate_tasks(n, A100, workload("mixed", "wide", A100), seed=seed)


def _items(schedule):
    return sorted(
        (it.task.id, it.node.key, it.begin, it.size) for it in schedule.items
    )


def test_registry_has_all_policies():
    names = set(available_policies())
    assert {"far", "miso", "fix-part", "fix-part-best", "online-greedy",
            "lower-bound"} <= names
    for name in names:
        pol = get_policy(name)
        assert isinstance(pol, SchedulerPolicy)
        assert pol.name == name
        assert get_policy(name) is pol  # singleton


def test_unknown_policy_raises():
    with pytest.raises(KeyError, match="far"):
        get_policy("definitely-not-a-policy")


def test_config_is_frozen():
    with pytest.raises(dataclasses.FrozenInstanceError):
        CFG.refine = False
    assert CFG.replace(refine=False).refine is False
    assert CFG.refine is True


@pytest.mark.parametrize("scaling,times", [("poor", "wide"),
                                           ("mixed", "wide"),
                                           ("good", "narrow")])
def test_far_policy_identical_to_schedule_batch(scaling, times):
    for seed in range(2):
        tasks = generate_tasks(
            15, A100, workload(scaling, times, A100), seed=seed
        )
        legacy = schedule_batch(tasks, A100)
        plan = get_policy("far").plan(tasks, A100, CFG)
        assert isinstance(plan, PlanResult)
        assert plan.makespan == legacy.makespan
        assert plan.assignment.node_tasks == legacy.assignment.node_tasks
        assert _items(plan.schedule) == _items(legacy.schedule)
        assert plan.extras["far"].winner_index == legacy.winner_index


def test_baseline_policies_identical_to_direct_calls():
    tasks = _t5_tasks(seed=3)
    assert _items(get_policy("miso").plan(tasks, A100, CFG).schedule) == \
        _items(miso_opt(tasks, A100))
    assert _items(get_policy("fix-part").plan(tasks, A100, CFG).schedule) == \
        _items(fix_part(tasks, A100, partition_of_ones(A100)))
    whole = CFG.replace(partition=partition_whole(A100))
    assert _items(get_policy("fix-part").plan(tasks, A100, whole).schedule) \
        == _items(fix_part(tasks, A100, partition_whole(A100)))
    best_plan = get_policy("fix-part-best").plan(tasks, A100, CFG)
    best_sched, best_part = fix_part_best(tasks, A100)
    assert _items(best_plan.schedule) == _items(best_sched)
    assert best_plan.extras["partition"] == best_part


def test_online_greedy_policy_identical_to_scheduler_loop():
    tasks = _t5_tasks(seed=5, n=12)
    sched = OnlineScheduler(A100)
    for t in tasks:
        sched.submit(t)
    plan = get_policy("online-greedy").plan(tasks, A100, CFG)
    assert _items(plan.schedule) == _items(sched.schedule())
    assert [p.node_key for p in plan.extras["placements"]] == \
        [p.node_key for p in sched.placements]


def test_every_policy_output_is_feasible():
    tasks = _t5_tasks(seed=1)
    for name in available_policies():
        plan = get_policy(name).plan(tasks, A100, CFG)
        if name == "lower-bound":
            assert plan.makespan == area_lower_bound(tasks, A100)
            continue
        # baselines carry no reconfig events (fixed partitions) — skip the
        # reconfiguration-sequence check for them, as the legacy tests do
        full = name in ("far", "online-greedy")
        validate_schedule(plan.schedule, tasks, check_reconfig=full)
        assert plan.makespan == plan.schedule.makespan
        assert plan.assignment is not None
        assert plan.policy == name


def test_lower_bound_policy_folds_multibatch_baseline():
    batches = [_t5_tasks(seed=s, n=8) for s in range(3)]
    flat = [t for b in batches for t in b]
    assert multibatch_baseline(batches, A100) == \
        get_policy("lower-bound").plan(flat, A100).makespan


@pytest.mark.parametrize("kwarg", sorted(LEGACY_KWARGS))
def test_legacy_kwargs_warn_once_and_match_config_path_exactly(kwarg):
    """Differential pin of the deprecation shim: each legacy boolean kwarg
    emits exactly ONE DeprecationWarning naming the SchedulerConfig field,
    and the resulting plan is bit-identical to the config path — items,
    assignment chains, winner index and makespan."""
    tasks = _t5_tasks(seed=0, n=6)
    # exercise the non-default value so the kwarg actually changes the plan
    value = 8 if kwarg == "max_refine_iterations" else \
        {"refine": False, "prune": False, "deep_refine": True,
         "use_engine": False}[kwarg]
    with pytest.warns(DeprecationWarning) as record:
        legacy = schedule_batch(tasks, A100, **{kwarg: value})
    shim_warnings = [
        w for w in record if issubclass(w.category, DeprecationWarning)
    ]
    assert len(shim_warnings) == 1
    msg = str(shim_warnings[0].message)
    assert f"schedule_batch({kwarg}=...)" in msg
    assert f"SchedulerConfig({LEGACY_KWARGS[kwarg]}=" in msg
    direct = schedule_batch(
        tasks, A100, SchedulerConfig(**{LEGACY_KWARGS[kwarg]: value})
    )
    assert legacy.makespan == direct.makespan
    assert legacy.winner_index == direct.winner_index
    assert legacy.evaluated == direct.evaluated
    assert legacy.assignment.node_tasks == direct.assignment.node_tasks
    assert _items(legacy.schedule) == _items(direct.schedule)
    assert legacy.schedule.reconfigs == direct.schedule.reconfigs


def test_legacy_kwargs_combine_and_warn_per_kwarg():
    """Several legacy kwargs in one call: one warning each, and the plan
    matches a single config carrying all of them."""
    tasks = _t5_tasks(seed=2, n=6)
    with pytest.warns(DeprecationWarning) as record:
        legacy = schedule_batch(tasks, A100, refine=False, prune=False)
    assert sum(
        issubclass(w.category, DeprecationWarning) for w in record
    ) == 2
    direct = schedule_batch(
        tasks, A100, SchedulerConfig(refine=False, prune=False)
    )
    assert _items(legacy.schedule) == _items(direct.schedule)
    assert legacy.makespan == direct.makespan


def test_unknown_schedule_batch_kwarg_raises():
    with pytest.raises(TypeError, match="unexpected keyword"):
        schedule_batch(_t5_tasks(n=3), A100, not_a_kwarg=True)


def test_tail_aware_plan_matches_manual_concatenate():
    """plan(tail=...) splices exactly like schedule_batch + concatenate —
    the t9 multi-batch seam path through the new surface."""
    b1, b2 = _t5_tasks(seed=0, n=8), _t5_tasks(seed=1, n=8)
    mb = MultiBatchScheduler(A100, config=SchedulerConfig())
    mb.add_batch(b1)
    far2 = schedule_batch(b2, A100)
    manual = concatenate(far2.assignment, mb.tail, mode="move_swap",
                         reverse=True)
    plan = get_policy("far").plan(
        b2, A100, SchedulerConfig(concat_mode="move_swap", reverse=True),
        tail=mb.tail,
    )
    assert _items(plan.schedule) == _items(manual.schedule)
    assert plan.tail.release == manual.tail.release
    assert plan.extras["concat"].moves == manual.moves


def test_multibatch_scheduler_matches_legacy_loop():
    """The registry-driven MultiBatchScheduler reproduces the legacy
    schedule_batch-per-batch driver bit-for-bit (t9 workload)."""
    batches = [
        generate_tasks(10, A100, workload("mixed", "wide", A100),
                       seed=s, id_offset=10_000 * s)
        for s in range(3)
    ]
    mb = MultiBatchScheduler(A100, mode="move_swap")
    for b in batches:
        mb.add_batch(b)
    tail, flip = Tail.empty(A100), False
    legacy_segments = []
    for b in batches:
        far = schedule_batch(b, A100)
        out = concatenate(far.assignment, tail, mode="move_swap",
                          reverse=flip)
        flip = not flip
        tail = out.tail
        legacy_segments.append(out.schedule)
    assert [_items(s) for s in mb.segments] == \
        [_items(s) for s in legacy_segments]
    assert mb.tail.release == tail.release
    validate_schedule(mb.combined_schedule(),
                      [t for b in batches for t in b])


def test_multibatch_scheduler_under_baseline_policy():
    """Any registered policy drives the multi-batch seam machinery."""
    batches = [
        generate_tasks(6, A100, workload("mixed", "wide", A100),
                       seed=s, id_offset=10_000 * s)
        for s in range(2)
    ]
    for name in ("miso", "fix-part", "online-greedy"):
        mb = MultiBatchScheduler(
            A100, policy=name, config=SchedulerConfig(concat_mode="trivial")
        )
        for b in batches:
            mb.add_batch(b)
        validate_schedule(mb.combined_schedule(),
                          [t for b in batches for t in b])
