"""Heterogeneous cluster layer: instance-typed Profiles (+ the size-keyed
back-compat shim, pinned differentially), phase-0 device partitioning,
the far-cluster policy (never worse than the best single device), the
per-driver reconfiguration fidelity fix, and cluster serving."""

import dataclasses

import pytest

from repro.core import (
    A30,
    A100,
    ClusterSpec,
    Profile,
    SchedulerConfig,
    SchedulingService,
    Task,
    cluster,
    get_policy,
    multi_gpu,
    partition_batch,
    schedule_batch,
    validate_cluster_schedule,
    validate_schedule,
)
from repro.core.cluster import ClusterMultiBatchScheduler, cluster_refine
from repro.core.repartition import Assignment
from repro.core.synth import generate_cluster_tasks, generate_tasks, workload
from repro.core.timing import TimingEngine

CFG = SchedulerConfig()
MIXED = cluster(A30, A100)


def _items(schedule):
    return sorted(
        (it.task.id, it.node.key, it.begin, it.size) for it in schedule.items
    )


# -- ClusterSpec structure ---------------------------------------------------

def test_cluster_trees_are_globally_unique():
    cs = cluster(A30, A100, multi_gpu(A30, 2))
    trees = [r.tree for d in cs.devices for r in d.roots]
    assert len(trees) == len(set(trees)) == 4
    assert cs.n_slices == 4 + 7 + 8
    assert cs.device_kinds == ("A30", "A100", "A30")
    for tree in trees:
        assert cs.device_of_tree(tree) in cs.devices


def test_cluster_split_schedule_roundtrip():
    tasks = generate_cluster_tasks(10, MIXED, "mixed", "wide", seed=1)
    plan = get_policy("far-cluster").plan(tasks, MIXED, CFG)
    merged_items = plan.schedule.items
    split = MIXED.split_schedule(plan.schedule)
    assert sum(len(s.items) for s in split) == len(merged_items)
    for dev, sched in zip(MIXED.devices, split):
        assert sched.spec is dev
        for it in sched.items:
            assert it.node.tree in {r.tree for r in dev.roots}


# -- Profile + the size-keyed shim -------------------------------------------

def test_profile_rejects_bare_size_keys():
    p = Profile({"A30": {1: 4.0, 2: 2.5, 4: 1.5}})
    with pytest.raises(KeyError, match="bind"):
        p[1]
    assert p[("A30", 2)] == 2.5
    assert p.for_kind("A30")[4] == 1.5
    assert p.supports("A30") and not p.supports("H100")
    with pytest.raises(KeyError, match="A100"):
        p.for_kind("A100")
    # flat (kind, size) construction is equivalent
    q = Profile({("A30", 1): 4.0, ("A30", 2): 2.5, ("A30", 4): 1.5})
    assert q == p


def test_size_keyed_shim_is_bit_identical_to_profile_binding():
    """The back-compat contract, pinned differentially: a batch of plain
    size-keyed tasks and the same batch wrapped in single-kind Profiles
    produce bit-identical FAR schedules on the matching device."""
    plain = generate_tasks(12, A30, workload("mixed", "wide", A30), seed=5)
    profiled = [
        dataclasses.replace(t, times=Profile({"A30": dict(t.times)}))
        for t in plain
    ]
    a = schedule_batch(plain, A30)
    b = schedule_batch(profiled, A30)
    assert a.makespan == b.makespan
    assert a.winner_index == b.winner_index
    assert a.assignment.node_tasks == b.assignment.node_tasks
    assert _items(a.schedule) == _items(b.schedule)
    assert a.schedule.reconfigs == b.schedule.reconfigs


def test_bind_is_identity_for_plain_tasks():
    t = Task(0, {1: 3.0, 2: 2.0, 4: 1.2})
    assert t.bind(A30) is t
    assert t.times_for("anything") is t.times
    assert t.supports("A100")
    p = Task(1, Profile({"A30": {1: 3.0}, "A100": {1: 2.0}}))
    bound = p.bind(A30)
    assert bound is not p and bound.times == {1: 3.0}
    assert p.supports("A100") and not p.supports("H100")


# -- phase 0: device partitioning --------------------------------------------

def test_partition_covers_batch_and_respects_support():
    tasks = generate_cluster_tasks(17, MIXED, "mixed", "wide", seed=2)
    # one task that only runs on the A100
    only_a100 = Task(
        9999, Profile({"A100": {1: 5.0, 2: 3.0, 3: 2.2, 4: 1.8, 7: 1.2}})
    )
    parts = partition_batch(tasks + [only_a100], MIXED)
    got = sorted(t.id for p in parts for t in p)
    assert got == sorted([t.id for t in tasks] + [9999])
    assert only_a100.id in {t.id for t in parts[1]}
    # unsupported everywhere -> loud error
    with pytest.raises(ValueError, match="fits no device"):
        partition_batch([Task(1, Profile({"H100": {1: 1.0}}))], MIXED)


def test_cluster_supports_matches_partitioner_predicate():
    """ClusterSpec.supports answers True exactly when partition_batch
    will accept the task (full size coverage on some device)."""
    full = generate_cluster_tasks(1, MIXED, "mixed", "wide", seed=0)[0]
    assert MIXED.supports(full)
    partial = Task(5, Profile({"A100": {7: 1.0}}))  # sizes 1..4 missing
    assert not MIXED.supports(partial)
    with pytest.raises(ValueError, match="fits no device"):
        partition_batch([partial], MIXED)


def test_partition_load_aware():
    """A busy device receives less new work than an idle twin."""
    cs = cluster(A30, A30)
    tasks = generate_tasks(10, A30, workload("mixed", "wide", A30), seed=0)
    even = partition_batch(tasks, cs)
    skewed = partition_batch(tasks, cs, loads=[1e6, 0.0])
    assert len(skewed[0]) < len(even[0])
    assert len(skewed[1]) == 10 - len(skewed[0])


# -- far-cluster -------------------------------------------------------------

@pytest.mark.parametrize("scaling,times", [("mixed", "wide"),
                                           ("poor", "narrow"),
                                           ("good", "wide")])
def test_far_cluster_valid_and_never_worse_than_best_single(scaling, times):
    far = get_policy("far")
    for seed in range(3):
        tasks = generate_cluster_tasks(
            14, MIXED, scaling, times, seed=seed
        )
        plan = get_policy("far-cluster").plan(tasks, MIXED, CFG)
        validate_cluster_schedule(plan.schedule, tasks)
        best_single = min(
            far.plan(tasks, dev, CFG).makespan for dev in MIXED.devices
        )
        assert plan.makespan <= best_single + 1e-9


def test_far_cluster_beats_best_single_on_benchmark_workloads():
    """The acceptance margin: on the t5-style mixed workload the pool
    strictly beats the best single device (there is real work to split)."""
    far = get_policy("far")
    tasks = generate_cluster_tasks(20, MIXED, "mixed", "wide", seed=0)
    plan = get_policy("far-cluster").plan(tasks, MIXED, CFG)
    best_single = min(
        far.plan(tasks, dev, CFG).makespan for dev in MIXED.devices
    )
    assert plan.makespan < best_single - 1e-6
    assert plan.extras["cluster"].mode == "partitioned"


def test_far_cluster_on_device_spec_delegates_to_far():
    tasks = generate_tasks(12, A100, workload("mixed", "wide", A100), seed=4)
    a = get_policy("far-cluster").plan(tasks, A100, CFG)
    b = get_policy("far").plan(tasks, A100, CFG)
    assert a.policy == "far-cluster"
    assert a.makespan == b.makespan
    assert _items(a.schedule) == _items(b.schedule)


def test_far_cluster_homogeneous_plain_tasks():
    """A homogeneous pool with plain size-keyed tasks needs no Profile."""
    cs = cluster(A30, A30)
    tasks = generate_tasks(12, A30, workload("mixed", "wide", A30), seed=7)
    plan = get_policy("far-cluster").plan(tasks, cs, CFG)
    validate_cluster_schedule(plan.schedule, tasks)
    single = get_policy("far").plan(tasks, A30, CFG).makespan
    assert plan.makespan < single  # two devices beat one


def test_far_cluster_empty_batch():
    plan = get_policy("far-cluster").plan([], MIXED, CFG)
    assert plan.makespan == 0.0
    assert plan.schedule.items == []


def test_far_cluster_single_device_fallback_wins_tiny_batch():
    """One short task: splitting buys nothing — the plan must match the
    best single device exactly (fallback or an equal partitioned plan)."""
    t = generate_cluster_tasks(1, MIXED, "good", "narrow", seed=0)
    plan = get_policy("far-cluster").plan(t, MIXED, CFG)
    far = get_policy("far")
    best_single = min(
        far.plan(t, dev, CFG).makespan for dev in MIXED.devices
    )
    assert plan.makespan == pytest.approx(best_single, abs=1e-9)


# -- cross-device engine primitives ------------------------------------------

def test_extract_place_undo_roundtrip():
    tasks = generate_tasks(8, A100, workload("mixed", "wide", A100), seed=3)
    asgn = schedule_batch(tasks, A100, SchedulerConfig(refine=False)).assignment
    eng = TimingEngine(asgn)
    before = ({k: list(v) for k, v in eng.chains.items() if v},
              eng.makespan())
    # a chain whose size has an alternative instance (size 7 has none)
    key = next(
        k for k, v in eng.chains.items()
        if v and sum(n.size == k[2] for n in A100.nodes) > 1
    )
    tid = eng.chains[key][0]
    other = next(
        n.key for n in A100.nodes if n.key != key and n.size == key[2]
    )
    eng.apply_extract(tid, key)
    assert tid not in eng.chains[key]
    eng.apply_place(tid, other)
    assert tid in eng.chains[other]
    assert eng.task_node[tid] == other
    eng.undo()   # un-place
    eng.undo()   # un-extract
    after = ({k: list(v) for k, v in eng.chains.items() if v},
             eng.makespan())
    assert after == before
    assert eng.task_node[tid] == key


def test_cluster_refine_improves_imbalanced_split():
    """Stuff every task onto one device of a twin pool: the inter-device
    search must move work across and cut the cluster makespan."""
    cs = cluster(A30, A30)
    tasks = generate_tasks(10, A30, workload("mixed", "wide", A30), seed=1)
    loaded = schedule_batch(tasks, cs.devices[0]).assignment
    engines = [TimingEngine(loaded),
               TimingEngine(Assignment(cs.devices[1], {}, {}))]
    before = max(e.makespan() for e in engines)
    moves, swaps = cluster_refine(
        cs, engines, {t.id: t for t in tasks}, max_edits=32
    )
    after = max(e.makespan() for e in engines)
    assert moves + swaps > 0
    assert after < before - 1e-9
    for dev, eng in zip(cs.devices, engines):
        validate_schedule(eng.schedule(), None)


# -- serving a heterogeneous pool --------------------------------------------

def _stream(cs, n, seed, **cfg_kw):
    import numpy as np

    tasks = generate_cluster_tasks(n, cs, "mixed", "wide", seed=seed)
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(2.0, size=n))
    svc = SchedulingService(
        pool=cs,
        config=SchedulerConfig(max_wait_s=5.0, max_batch=8, **cfg_kw),
    )
    for t, a in zip(tasks, arrivals):
        svc.submit(t, arrival=float(a), deadline=float(a) + 400.0)
    combined = svc.drain()
    return svc, combined, tasks


def test_cluster_service_flushes_and_validates_per_device():
    svc, combined, tasks = _stream(MIXED, 24, seed=0)
    assert svc.stats.batches >= 1
    assert sorted(it.task.id for it in combined.items) == \
        sorted(t.id for t in tasks)
    for dev_sched in MIXED.split_schedule(combined):
        validate_schedule(dev_sched, None, check_reconfig=False)
    # both devices actually host work
    hosting = {MIXED.tree_device[it.node.tree] for it in combined.items}
    assert hosting == {0, 1}
    # decisions are causal
    decided = {d.task_id: d.decided_at for d in svc.stats.decisions}
    for it in combined.items:
        assert it.begin >= decided[it.task.id] - 1e-9


def test_cluster_service_replan_never_worse():
    plain, _, _ = _stream(MIXED, 20, seed=3, replan=False)
    re, _, _ = _stream(MIXED, 20, seed=3, replan=True)
    assert re.makespan <= plain.makespan + 1e-9


def test_cluster_service_trickle_goes_online():
    import numpy as np

    tasks = generate_cluster_tasks(4, MIXED, "mixed", "wide", seed=9)
    svc = SchedulingService(
        pool=MIXED, config=SchedulerConfig(max_wait_s=1.0, max_batch=16),
    )
    arrivals = np.arange(4) * 100.0  # far apart -> every flush a trickle
    for t, a in zip(tasks, arrivals):
        svc.submit(t, arrival=float(a))
    combined = svc.drain()
    assert svc.stats.online_placements == 4
    for dev_sched in MIXED.split_schedule(combined):
        validate_schedule(dev_sched, None, check_reconfig=False)


def test_cluster_service_rejects_unsupported_profile_at_intake():
    """A task no device fully covers must be refused at submit — letting
    it queue would crash the next batch flush mid-partitioning and drop
    every co-queued task with it."""
    svc = SchedulingService(pool=MIXED, config=SchedulerConfig(max_batch=8))
    bad = Task(77, Profile({"A100": {1: 5.0, 2: 3.0, 4: 2.0}}))  # no 3, 7
    assert svc.submit(bad, arrival=0.0) == "rejected"
    assert 77 in svc.stats.rejected
    good = generate_cluster_tasks(3, MIXED, "mixed", "wide", seed=1)
    for i, t in enumerate(good):
        svc.submit(t, arrival=0.1 * i)
    combined = svc.drain()  # flush must survive — the bad task never queued
    assert sorted(it.task.id for it in combined.items) == \
        sorted(t.id for t in good)


def test_cluster_service_admission_uses_pool_floor():
    """A deadline only the fast device can meet must not be rejected."""
    svc = SchedulingService(
        pool=MIXED, config=SchedulerConfig(admission="reject"),
    )
    t = Task(0, Profile({
        "A30": {1: 100.0, 2: 60.0, 4: 40.0},
        "A100": {1: 10.0, 2: 6.0, 3: 4.5, 4: 4.0, 7: 3.0},
    }))
    # floor over the pool is 3.0s (A100 size-7); 35 < 40 (best A30) but
    # comfortably above the pool floor -> must be admitted
    assert svc.submit(t, arrival=0.0, deadline=35.0) == "queued"
    t2 = dataclasses.replace(t, id=1)
    assert svc.submit(t2, arrival=0.0, deadline=1.0) == "rejected"


def test_cluster_approximation_factor_and_per_device_theorem1():
    """The pool's certificate is the worst device's §5 factor, and every
    device's rigid sub-schedule respects its own Theorem-1 bound."""
    from repro.core.bounds import (
        cluster_approximation_factor,
        theorem1_rigid_bound,
    )
    from repro.core.repartition import replay

    assert cluster_approximation_factor(MIXED) == 2.0  # A100 dominates 7/4
    tasks = generate_cluster_tasks(16, MIXED, "mixed", "wide", seed=4)
    plan = get_policy("far-cluster").plan(tasks, MIXED, CFG)
    for asgn in plan.extras["cluster"].assignments:
        if asgn is None or not asgn.node_tasks:
            continue
        rigid = replay(asgn, include_reconfig=False)
        assert rigid.makespan <= theorem1_rigid_bound(rigid) + 1e-6


# -- per-driver reconfiguration sequences (satellite fidelity fix) ----------

def test_multi_gpu_reconfig_decouples_trees():
    spec_tree = multi_gpu(A100, 2)
    spec_global = dataclasses.replace(spec_tree, reconfig_scope="global")
    no_refine = SchedulerConfig(refine=False)
    strict_wins = 0
    for seed in range(4):
        tasks = generate_tasks(
            24, spec_tree, workload("mixed", "wide", spec_tree), seed=seed
        )
        a = schedule_batch(tasks, spec_tree, no_refine)
        b = schedule_batch(tasks, spec_global, no_refine)
        validate_schedule(a.schedule, tasks)
        validate_schedule(b.schedule, tasks)
        # per-assignment the decoupled timing dominates (creations only
        # move earlier), so the phase-2 winner can never be worse …
        assert a.makespan <= b.makespan + 1e-9
        if a.makespan < b.makespan - 1e-9:
            strict_wins += 1
        # … and the refined pipelines stay feasible under both scopes
        validate_schedule(schedule_batch(tasks, spec_tree).schedule, tasks)
        validate_schedule(schedule_batch(tasks, spec_global).schedule, tasks)
    # the fidelity fix actually binds on some of the workloads
    assert strict_wins >= 1


def test_single_tree_scope_is_bit_identical():
    spec_global = dataclasses.replace(A100, reconfig_scope="global")
    tasks = generate_tasks(14, A100, workload("mixed", "wide", A100), seed=6)
    a = schedule_batch(tasks, A100)
    b = schedule_batch(tasks, spec_global)
    assert a.makespan == b.makespan
    assert _items(a.schedule) == _items(b.schedule)
    assert a.schedule.reconfigs == b.schedule.reconfigs


# -- hypothesis property -----------------------------------------------------

try:
    from hypothesis import given, settings
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def profile_batches(draw):
        n = draw(st.integers(1, 8))
        tasks = []
        for i in range(n):
            table = {}
            for dev in MIXED.devices:
                t1 = draw(st.floats(0.5, 60.0, allow_nan=False))
                times, cur = {}, t1
                for s in dev.sizes:
                    if s != min(dev.sizes):
                        cur *= draw(st.floats(0.3, 1.0))
                    times[s] = cur
                table[dev.device_kind] = times
            tasks.append(Task(id=i, times=Profile(table)))
        return tasks

    @settings(max_examples=25, deadline=None)
    @given(profile_batches())
    def test_cluster_never_exceeds_best_single_device(tasks):
        plan = get_policy("far-cluster").plan(tasks, MIXED, CFG)
        validate_cluster_schedule(plan.schedule, tasks)
        far = get_policy("far")
        best = min(
            far.plan(tasks, dev, CFG).makespan for dev in MIXED.devices
        )
        assert plan.makespan <= best + 1e-9
