import os
import sys

# keep single-device defaults for tests (the 512-device dry-run sets its own
# XLA_FLAGS in a separate process); make src importable without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
