"""Golden-bad: logged opcodes without exact undo inverses."""


class LeakyState:
    def __init__(self):
        self._log = []
        self.items = {}

    def apply_put(self, key, value):
        old = self.items.get(key)
        self.items[key] = value
        self._log.append(("put", key, old))

    def apply_drop(self, key):
        old = self.items.pop(key)
        self._log.append(("drop", key, old))  # finding: no undo branch

    def undo(self):
        entry = self._log.pop()
        kind = entry[0]
        if kind == "put":
            _, key, old = entry
            if old is None:
                del self.items[key]
            else:
                self.items[key] = old
        else:
            raise AssertionError(f"unknown log entry {kind}")


class MisalignedState:
    def __init__(self):
        self._log = []
        self.slots = []

    def apply_push(self, value, marker):
        self.slots.append(value)
        self._log.append(("push", value, marker))

    def undo(self):
        entry = self._log.pop()
        kind = entry[0]
        if kind == "push":
            _, value = entry            # finding: arity mismatch (2 vs 3)
            self.slots.pop()
        else:
            raise AssertionError(f"unknown log entry {kind}")
