"""Golden-clean: subclasses keep the contract by delegating or by
explicitly refusing (the ReplayEngine pattern)."""


class BaseState:
    def __init__(self):
        self._log = []
        self.items = []

    def apply_add(self, value):
        self.items.append(value)
        self._log.append(("add", value))

    def undo(self):
        entry = self._log.pop()
        kind = entry[0]
        if kind == "add":
            _, value = entry
            self.items.pop()
        else:
            raise AssertionError(f"unknown log entry {kind}")


class Delegating(BaseState):
    def apply_add(self, value):
        super().apply_add(value)        # delegation keeps the log exact


class Refusing(BaseState):
    def apply_add(self, value):
        raise NotImplementedError(
            "this engine cannot honour add; use BaseState"
        )
