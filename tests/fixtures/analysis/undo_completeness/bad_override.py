"""Golden-bad: subclass silently breaking the log/undo contract, plus
an undo() that swallows unknown opcodes."""


class BaseState:
    def __init__(self):
        self._log = []
        self.items = []

    def apply_add(self, value):
        self.items.append(value)
        self._log.append(("add", value))

    def undo(self):
        entry = self._log.pop()
        kind = entry[0]
        if kind == "add":
            _, value = entry
            self.items.pop()
        # finding: no terminal raise — unknown kinds silently skipped


class QuietOverride(BaseState):
    def apply_add(self, value):         # finding: drops the log entry
        self.items.append(value)
