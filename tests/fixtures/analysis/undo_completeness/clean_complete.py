"""Golden-clean: every opcode has an exact inverse and unknown kinds
raise."""


class CompleteState:
    def __init__(self):
        self._log = []
        self.items = {}

    def apply_put(self, key, value):
        old = self.items.get(key)
        self.items[key] = value
        self._log.append(("put", key, old))

    def apply_drop(self, key):
        old = self.items.pop(key)
        self._log.append(("drop", key, old))

    def undo(self):
        entry = self._log.pop()
        kind = entry[0]
        if kind == "put":
            _, key, old = entry
            if old is None:
                del self.items[key]
            else:
                self.items[key] = old
        elif kind == "drop":
            _, key, old = entry
            self.items[key] = old
        else:
            raise AssertionError(f"unknown log entry {kind}")
