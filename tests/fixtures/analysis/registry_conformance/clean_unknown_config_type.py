"""Golden-clean: a `config`/`cfg` name that is NOT a SchedulerConfig is
out of scope — inference is annotation/constructor-driven, so model
configs sharing the variable name never false-positive."""


class ModelConfig:
    n_layers: int = 12


def flops(cfg: ModelConfig):
    return cfg.n_layers * cfg.d_model_maybe_missing


def untyped(config):
    return config.whatever_field
