"""Golden-clean: protocol-shaped registered plugins and real fields."""


class SchedulerConfig:
    refine: bool = True
    seed: int = 0

    def replace(self, **changes):
        return self


def register_policy(name):
    def deco(cls):
        return cls
    return deco


def register_evaluator(name):
    def deco(cls):
        return cls
    return deco


@register_policy("full")
class FullPolicy:
    def plan(self, tasks, spec, config=None, tail=None):
        return tasks


@register_policy("hooked")
class HookedPolicy:
    def _plan_fresh(self, tasks, spec, config):
        return config.refine and config.seed


@register_evaluator("proper")
class ProperEvaluator:
    def evaluate(self, tasks, spec, first, deltas, config):
        return config.seed
