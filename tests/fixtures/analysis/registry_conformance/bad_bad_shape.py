"""Golden-bad: registered plugins that do not satisfy the protocol."""


def register_policy(name):
    def deco(cls):
        return cls
    return deco


def register_evaluator(name):
    def deco(cls):
        return cls
    return deco


@register_policy("stub")
class StubPolicy:                       # finding: no plan/_plan_fresh
    def solve(self, tasks):
        return tasks


@register_policy("short")
class ShortPolicy:
    def plan(self, tasks):              # finding: protocol arity
        return tasks


@register_evaluator("mute")
class MuteEvaluator:                    # finding: no evaluate()
    def score(self, tasks):
        return 0.0


@register_evaluator("narrow")
class NarrowEvaluator:
    def evaluate(self, tasks, spec):    # finding: protocol arity
        return None
