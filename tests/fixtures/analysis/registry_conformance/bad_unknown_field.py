"""Golden-bad: reads of SchedulerConfig fields that do not exist."""


class SchedulerConfig:
    refine: bool = True
    seed: int = 0
    eps: float = 1e-9

    def replace(self, **changes):
        return self


def plan_with(config: SchedulerConfig):
    if config.refine:
        return config.seed
    return config.max_refine_iters      # finding: typo'd field


def tuned(config: SchedulerConfig):
    fresh = config.replace(seed=1)
    return fresh.epsilon                # finding: unknown field
