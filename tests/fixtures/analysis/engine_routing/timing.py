"""Golden-clean: the blessed module name may use the replay layer —
this file exists to pin the basename blessing, not as real code."""

from repro.core.repartition import replay


def reference_score(assignment):
    return replay(assignment).makespan  # blessed: timing.py owns replay


def internals(eng, key):
    return eng.durs[key]                # blessed inside timing.py
