"""Golden-bad: reaching into engine internals from outside timing.py."""


def fold_chain(eng, key):
    return sum(eng.durs[key])           # finding: .durs internal


def peek_log(eng):
    return len(eng._log)                # finding: ._log internal


def corrected(eng, tid):
    return eng.stretched.get(tid)       # finding: .stretched internal
