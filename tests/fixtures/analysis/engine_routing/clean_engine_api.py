"""Golden-clean: timing consumed through the public engine API."""

from repro.core.timing import chains_makespan, make_engine


def score_candidate(assignment):
    eng = make_engine(assignment)
    return eng.makespan()


def score_chains(spec, node_tasks, node_durs):
    return chains_makespan(spec, node_tasks, node_durs)


def chain_view(eng, key):
    return list(eng.chain_durations(key))


def rollback_token(eng):
    return eng.log_length
