"""Golden-bad: replaying per candidate instead of using the engine."""

from repro.core.repartition import replay


def score_candidate(assignment):
    return replay(assignment).makespan  # finding: direct replay() call


def score_all(assignments):
    return [replay(a).makespan for a in assignments]  # finding
