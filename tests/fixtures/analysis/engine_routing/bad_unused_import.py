"""Golden-bad: dead import of the replay layer."""

from repro.core.repartition import replay  # finding: unused import


def makespan_of(engine):
    return engine.makespan()
