"""Golden-bad: a pragma without a justification is itself a finding."""

import time


def stamp():
    return time.time()  # contracts: ignore[determinism]
