"""Golden-clean: a violation suppressed with a justified pragma."""

import time


def stamp():
    return time.time()  # contracts: ignore[determinism] -- fixture: instrumentation only, pinned by golden test
