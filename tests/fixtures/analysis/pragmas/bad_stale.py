"""Golden-bad: a pragma on a line with no matching finding is stale."""


def add(a, b):
    return a + b  # contracts: ignore[determinism] -- nothing here violates anything
