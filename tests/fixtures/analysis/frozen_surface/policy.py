"""Golden-clean: the defining module owns the builder idiom — this
file pins the basename blessing (mirrors BasePolicy.plan finalising the
PlanResult it just built)."""


def plan(self, tasks, spec, config, tail):
    res = self._plan_fresh(tasks, spec, config)
    res.policy = self.name              # blessed: defining module
    res.tail = tail
    return res
