"""Golden-bad: mutating frozen-surface instances."""

from repro.core.policy import SchedulerConfig


def retune(config: SchedulerConfig):
    config.seed = 1                     # finding: mutate SchedulerConfig
    return config


def rebuild():
    cfg = SchedulerConfig(refine=False)
    cfg.eps = 0.0                       # finding: mutate SchedulerConfig
    return cfg


def forced(task):
    object.__setattr__(task, "id", 0)   # finding: frozen bypass
    return task
