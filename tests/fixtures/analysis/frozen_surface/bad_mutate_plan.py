"""Golden-bad: rewriting a PlanResult after the policy produced it."""


def relabel(policy, tasks, spec, config):
    res = policy.plan(tasks, spec, config, None)
    res.policy = "renamed"              # finding: mutate PlanResult
    return res


def clamp(policy, tasks, spec, config):
    plan = policy.plan(tasks, spec, config, None)
    plan.makespan = 0.0                 # finding: mutate PlanResult
    return plan
