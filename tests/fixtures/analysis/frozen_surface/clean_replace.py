"""Golden-clean: new instances via constructors and replace()."""

import dataclasses

from repro.core.policy import SchedulerConfig


def retune(config: SchedulerConfig):
    return config.replace(seed=1)


def relabel(policy, tasks, spec, config):
    res = policy.plan(tasks, spec, config, None)
    return dataclasses.replace(res, policy="renamed")


def extras_are_fine(policy, tasks, spec, config):
    # mutating the *contents* of a result's extras dict is the documented
    # extension point; only attribute assignment is fenced
    res = policy.plan(tasks, spec, config, None)
    res.extras["note"] = "ok"
    return res
