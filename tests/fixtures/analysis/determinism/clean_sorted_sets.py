"""Golden-clean: sets consumed order-insensitively or via sorted()."""


def deterministic_order(nodes, used):
    free = {n for n in nodes if n not in used}
    for node in sorted(free):           # sorted(): deterministic
        return node
    return None


def membership_only(keys, candidates):
    wanted = set(keys)
    return [c for c in candidates if c in wanted]


def unordered_build(active):
    # building unordered containers from a set leaks no order
    ready = {k: 0.0 for k in active}
    mirror = {k for k in active}
    return ready.get(None), len(mirror)


def reductions(values):
    pool = set(values)
    return min(pool), max(pool), sum(pool), len(pool)
