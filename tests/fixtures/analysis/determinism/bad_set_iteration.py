"""Golden-bad: set iteration order reaching tie-break decisions."""


def first_fit(nodes, used):
    free = {n for n in nodes if n not in used}
    for node in free:                   # finding: set iteration
        return node
    return None


def order_keys(keys):
    pending = set(keys)
    ordered = [k for k in pending]      # finding: comprehension over set
    pending_pop = set(keys).pop()       # finding: arbitrary element
    return ordered, pending_pop


def id_keyed(cache, spec):
    cache[id(spec)] = spec              # finding: id()-based key
    return cache


def leaked_dict_order(active):
    ready = {k: 0.0 for k in set(active)}
    return [k for k in ready.values()]  # finding: set-ordered dict
