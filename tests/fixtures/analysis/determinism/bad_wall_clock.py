"""Golden-bad: wall-clock reads leaking into scheduling state."""

import time
from datetime import datetime


def stamp_arrival(task):
    task_arrival = time.time()          # finding: wall-clock
    return task_arrival


def batch_label():
    return datetime.now().isoformat()   # finding: wall-clock
