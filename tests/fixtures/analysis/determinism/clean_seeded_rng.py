"""Golden-clean: seeded constructors and instrumentation-only timing."""

import random
import time

import numpy as np


def seeded_stream(seed):
    rng = random.Random(seed)           # seeded constructor: blessed
    return rng.random()


def seeded_numpy(seed):
    gen = np.random.default_rng(seed)   # seeded: blessed
    return gen.random()


def timed_plan(fn):
    t0 = time.perf_counter()            # instrumentation-only: allowed
    out = fn()
    return out, time.perf_counter() - t0
