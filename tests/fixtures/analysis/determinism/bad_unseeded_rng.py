"""Golden-bad: unseeded / process-global RNG in a decision path."""

import random

import numpy as np


def jitter_order(tasks):
    rng = random.Random()               # finding: unseeded constructor
    return sorted(tasks, key=lambda t: rng.random())


def shuffle_batch(tasks):
    random.shuffle(tasks)               # finding: module-global RNG
    return tasks


def noise():
    gen = np.random.default_rng()       # finding: unseeded default_rng
    return gen.random()
