"""Hypothesis property tests on the scheduler's invariants."""

import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (
    A30, A100, H100, TPU_POD_256,
    SchedulerConfig, Task, schedule_batch, validate_schedule,
)
from repro.core.bounds import theorem1_rigid_bound
from repro.core.multibatch import MultiBatchScheduler, Tail, concatenate
from repro.core.repartition import replay

SPECS = {"A30": A30, "A100": A100, "H100": H100, "TPU": TPU_POD_256}


@st.composite
def two_task_batches(draw, max_tasks=8):
    name = draw(st.sampled_from(sorted(SPECS)))
    spec, t1 = draw(task_batches(max_tasks, spec_name_fixed=name))
    _, t2raw = draw(task_batches(max_tasks, spec_name_fixed=name))
    t2 = [Task(id=100 + t.id, times=t.times) for t in t2raw]
    return spec, t1, t2


@st.composite
def task_batches(draw, max_tasks=12, spec_name_fixed=None):
    """Random batch with monotone-non-increasing times (paper monotony 1);
    the per-size times are otherwise arbitrary — work may be non-monotone,
    including the super-linear regime."""
    spec_name = spec_name_fixed or draw(st.sampled_from(sorted(SPECS)))
    spec = SPECS[spec_name]
    n = draw(st.integers(1, max_tasks))
    tasks = []
    for i in range(n):
        t1 = draw(st.floats(0.5, 200.0, allow_nan=False))
        times = {}
        cur = t1
        for s in spec.sizes:
            if s == min(spec.sizes):
                times[s] = cur
            else:
                shrink = draw(st.floats(0.3, 1.0))
                cur = cur * shrink
                times[s] = cur
        tasks.append(Task(id=i, times=times))
    return spec, tasks


@settings(max_examples=40, deadline=None)
@given(task_batches())
def test_far_always_feasible(batch):
    spec, tasks = batch
    res = schedule_batch(tasks, spec)
    validate_schedule(res.schedule, tasks)


@settings(max_examples=40, deadline=None)
@given(task_batches())
def test_far_within_certified_factor_of_area_bound(batch):
    """ω(no reconfig) ≤ Theorem-1 bound for the winning allocation."""
    spec, tasks = batch
    res = schedule_batch(tasks, spec, SchedulerConfig(refine=False))
    nr = replay(res.assignment, include_reconfig=False)
    assert nr.makespan <= theorem1_rigid_bound(nr) + 1e-6


@settings(max_examples=40, deadline=None)
@given(task_batches())
def test_every_task_runs_exactly_once_at_molded_size(batch):
    spec, tasks = batch
    res = schedule_batch(tasks, spec)
    seen = {}
    for it in res.schedule.items:
        assert it.task.id not in seen
        seen[it.task.id] = it.size
        assert it.size == it.node.size
        assert it.size in spec.sizes
    assert len(seen) == len(tasks)


@settings(max_examples=25, deadline=None)
@given(two_task_batches(),
       st.sampled_from(["trivial", "reverse", "move_swap"]))
def test_multibatch_concat_always_feasible(batches, mode):
    spec, t1, t2 = batches
    mb = MultiBatchScheduler(spec, mode=mode)
    mb.add_batch(t1)
    mb.add_batch(t2)
    combined = mb.combined_schedule()
    validate_schedule(combined, t1 + t2)


@settings(max_examples=25, deadline=None)
@given(two_task_batches())
def test_auto_concat_no_worse_than_trivial_per_seam(batches):
    """For a FIXED committed tail, "auto" picks the best seam strategy, so
    its segment makespan can never lose to the trivial barrier concat.
    (Plain "reverse" CAN lose on very short tasks where its extra
    reconfigurations dominate — hypothesis found that counter-example, and
    the paper's own caveat about short tasks agrees — and greedy per-seam
    choices are not *globally* optimal across later batches, so the
    guarantee is stated per seam.)"""
    from repro.core.far import schedule_batch

    spec, t1, t2 = batches
    mb = MultiBatchScheduler(spec, mode="trivial")
    mb.add_batch(t1)
    tail = mb.tail
    far2 = schedule_batch(t2, spec)
    auto = concatenate(far2.assignment, tail, mode="auto")
    triv = concatenate(far2.assignment, tail, mode="trivial")
    assert auto.schedule.makespan <= triv.schedule.makespan + 1e-6


@settings(max_examples=40, deadline=None)
@given(task_batches(), st.booleans())
def test_vectorized_evaluator_matches_sequential(batch, prune):
    """Family-evaluator equivalence contract (repro.core.family_eval):
    the vectorized array-program scorer picks the bit-identical winner —
    index, allocation, assignment, pre-refine makespan and evaluated
    count — as the sequential reference, pruned or not, on every spec."""
    spec, tasks = batch
    rs = schedule_batch(tasks, spec, SchedulerConfig(
        evaluator="sequential", prune=prune, refine=False))
    rv = schedule_batch(tasks, spec, SchedulerConfig(
        evaluator="vectorized", prune=prune, refine=False))
    assert rs.winner_index == rv.winner_index
    assert rs.allocation == rv.allocation
    assert rs.makespan_before_refine == rv.makespan_before_refine
    assert rs.evaluated == rv.evaluated
    assert rs.assignment.node_tasks == rv.assignment.node_tasks
    assert rs.schedule.items == rv.schedule.items


@settings(max_examples=30, deadline=None)
@given(task_batches(max_tasks=10), st.data())
def test_degraded_spec_still_schedules(batch, data):
    spec, tasks = batch
    cells = [(r.tree, s) for r in spec.roots for s in r.blocked]
    dead = data.draw(
        st.lists(st.sampled_from(cells), min_size=1,
                 max_size=max(1, spec.n_slices // 2), unique=True)
    )
    degraded = spec.degrade(dead)
    if not degraded.roots:
        return
    # keep only profiles for sizes that still exist
    tasks2 = [
        Task(id=t.id, times={s: t.times[s] for s in degraded.sizes})
        for t in tasks
    ]
    res = schedule_batch(tasks2, degraded)
    validate_schedule(res.schedule, tasks2)
