"""H4 regression: KV-length-sharded decode lowers and runs on a mesh whose
model axis does not divide the kv-head count (flash-decoding layout)."""

import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
from repro.configs import SMOKES
from repro.launch.mesh import mesh_shape_dict
from repro.models.config import ShapeConfig
from repro.models.model import build_model
from repro.parallel.sharding import make_rules
from repro.parallel.steps import make_decode_step, make_prefill_step

cfg = SMOKES["qwen2.5-3b"]         # kv=2: cannot shard over a 4-way axis
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = make_rules(cfg, mesh_shape_dict(mesh), fsdp=False, batch_size=2)
assert rules.rules["kv_heads"] == ()
assert rules.rules["kv_len"] == ("model",)

model = build_model(cfg)
shape = ShapeConfig("d", 32, 2, "decode")
pre = make_prefill_step(model, rules, mesh, ShapeConfig("p", 32, 2, "prefill"))
dec = make_decode_step(model, rules, mesh, shape)
with mesh:
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 36), 0, cfg.vocab_size)
    pfn = jax.jit(pre.fn, in_shardings=pre.in_shardings,
                  out_shardings=pre.out_shardings)
    dfn = jax.jit(dec.fn, in_shardings=dec.in_shardings,
                  out_shardings=dec.out_shardings,
                  donate_argnums=dec.donate_argnums)
    lg, cache = pfn(params, {"tokens": toks[:, :32]})
    for i in range(32, 36):
        lg, cache = dfn(params, cache, toks[:, i:i + 1])

# ground truth on the same devices without the sharded cache
ref_model = build_model(cfg)
lg_ref, _ = ref_model.prefill(params, {"tokens": toks})
import numpy as np
err = float(jnp.max(jnp.abs(lg.astype(jnp.float32) - lg_ref.astype(jnp.float32))))
assert err < 0.35, err   # bf16 path divergence only
print("KV_SHARD_OK", err)
"""


def test_kv_length_sharded_decode_runs_and_matches():
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT, "src"],
        capture_output=True, text=True, timeout=900, cwd=".",
    )
    assert "KV_SHARD_OK" in out.stdout, out.stdout[-1500:] + out.stderr[-3000:]
