"""Hypothesis property tests for the deadline-aware serving layer.

Random arrival streams with random deadlines must satisfy the three
re-planning contracts:

1. ``replan=True`` never ends a stream with a larger makespan than
   ``replan=False`` on the same submissions;
2. no task ever starts before the flush decision that placed it (nor
   before its own arrival);
3. tasks that have started are never moved by a later flush — the
   no-preemption model survives re-planning.
"""

import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from invariants import assert_valid_schedule, service_floors
from repro.core import A100, SchedulerConfig, SchedulingService, Task
from repro.core.problem import validate_schedule


@st.composite
def arrival_streams(draw, max_tasks=12):
    """A random stream: monotone times per task, bursty-or-sparse gaps,
    and a deadline (sometimes tight, sometimes absent) per task."""
    n = draw(st.integers(3, max_tasks))
    tasks, arrivals, deadlines = [], [], {}
    now = 0.0
    for i in range(n):
        t1 = draw(st.floats(0.5, 60.0, allow_nan=False))
        times, cur = {}, t1
        for s in A100.sizes:
            if s != min(A100.sizes):
                cur = cur * draw(st.floats(0.3, 1.0))
            times[s] = cur
        tasks.append(Task(id=i, times=times))
        now += draw(st.sampled_from([0.0, 0.2, 1.0, 5.0, 40.0]))
        arrivals.append(now)
        slack = draw(st.sampled_from([None, 0.1, 2.0, 50.0, 1e6]))
        if slack is not None:
            deadlines[i] = now + slack
    budget = draw(st.sampled_from([1.0, 4.0, 15.0]))
    max_batch = draw(st.sampled_from([3, 6, 32]))
    return tasks, arrivals, deadlines, budget, max_batch


def _run(stream, replan, record=None):
    tasks, arrivals, deadlines, budget, max_batch = stream
    svc = SchedulingService(
        A100,
        config=SchedulerConfig(
            max_wait_s=budget, max_batch=max_batch, replan=replan,
        ),
    )
    prev_items, prev_flushes = set(), 0
    for t, a in zip(tasks, arrivals):
        svc.submit(t, arrival=a, deadline=deadlines.get(t.id))
        if record is not None and svc._flush_id > prev_flushes:
            decided = [
                d.decided_at for d in svc.stats.decisions
                if d.flush_id > prev_flushes
            ]
            record.append((prev_items, min(decided),
                           {x for x in _items(svc.mb.combined_schedule())}))
        if record is not None:
            prev_flushes = svc._flush_id
            prev_items = set(_items(svc.mb.combined_schedule()))
    combined = svc.drain()
    return svc, combined


def _items(schedule):
    return [
        (it.task.id, it.node.key, it.begin, it.end) for it in schedule.items
    ]


@settings(max_examples=25, deadline=None)
@given(arrival_streams())
def test_replan_contracts_on_random_streams(stream):
    tasks, arrivals, deadlines, _, _ = stream
    snapshots = []
    svc_plain, c_plain = _run(stream, replan=False)
    svc_re, c_re = _run(stream, replan=True, record=snapshots)

    # contract 1: re-planning never increases the stream makespan
    assert svc_re.makespan <= svc_plain.makespan + 1e-9

    # both timelines are feasible and complete
    validate_schedule(c_plain, tasks, check_reconfig=False)
    validate_schedule(c_re, tasks, check_reconfig=False)
    assert_valid_schedule(c_re, A100, tasks=tasks,
                          floors=service_floors(svc_re))

    # contract 2: nothing starts before the decision that placed it (the
    # re-planning chain obeys the *latest* decision per task; the reported
    # winner obeys at least the first)
    arrived = dict(zip((t.id for t in tasks), arrivals))
    last = {}
    for d in svc_re.stats.decisions:
        last[d.task_id] = d.decided_at
    for tid, key, begin, _ in _items(svc_re.mb.combined_schedule()):
        assert begin >= last[tid] - 1e-9
        assert begin >= arrived[tid] - 1e-9
    for tid, key, begin, _ in _items(c_re):
        assert begin >= arrived[tid] - 1e-9

    # contract 3: items started by a flush decision survive it untouched
    for before, cutoff, after in snapshots:
        for item in before:
            if item[2] <= cutoff + 1e-9:
                assert item in after

    # deadline bookkeeping: a reported miss really misses, a non-miss
    # really completes in time
    rep = svc_re.deadline_report()
    ends = {tid: end for tid, _, _, end in _items(c_re)}
    for tid, dl in deadlines.items():
        if tid in rep["missed"]:
            assert ends[tid] > dl
        else:
            assert ends[tid] <= dl + 1e-9


@settings(max_examples=10, deadline=None)
@given(arrival_streams(max_tasks=8))
def test_admission_reject_only_refuses_provable_misses(stream):
    """Every rejected task's deadline is indeed unmeetable: even its
    best-case completion (the admission lower bound at submit time) lies
    beyond the deadline — and with admission off, the same stream's
    accepted-task placements confirm the bound was no excuse."""
    tasks, arrivals, deadlines, budget, max_batch = stream
    svc = SchedulingService(
        A100,
        config=SchedulerConfig(
            max_wait_s=budget, max_batch=max_batch, admission="reject",
        ),
    )
    verdicts, bounds = {}, {}
    for t, a in zip(tasks, arrivals):
        # fire any due flush first, so the bound captured here is exactly
        # the one the admission check inside submit() will consult
        svc.poll(a)
        bounds[t.id] = svc.completion_lower_bound(t, a)
        verdicts[t.id] = svc.submit(t, arrival=a, deadline=deadlines.get(t.id))
    combined = svc.drain()
    scheduled = {it.task.id for it in combined.items}
    for t, a in zip(tasks, arrivals):
        dl = deadlines.get(t.id)
        if verdicts[t.id] == "rejected":
            assert t.id not in scheduled
            # provable: the admission floor at submit time blows the
            # deadline (and it only ever tightens the context-free
            # best-case bound, never undercuts it)
            assert bounds[t.id] > dl
            assert bounds[t.id] >= a + min(t.times.values()) - 1e-9
        else:
            assert t.id in scheduled
            if dl is not None:
                assert bounds[t.id] <= dl + 1e-9
