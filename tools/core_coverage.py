#!/usr/bin/env python
"""Line coverage of ``src/repro/core`` with zero external dependencies.

CI runs the scheduler-core test files under this tool and fails the job
when coverage drops below the recorded floor (the measured baseline minus
a one-point margin), so test regressions surface in PRs without adding a
coverage package to the image.

  PYTHONPATH=src python tools/core_coverage.py --fail-under 85 -- -q tests/test_policy.py ...

How it measures:

* **executable lines** come from compiling each ``src/repro/core/*.py``
  file and collecting the line numbers of every (recursively nested) code
  object via ``co_lines()`` — exactly the lines that *can* fire a line
  event, so numerator and denominator share one definition;
* **executed lines** are recorded with ``sys.monitoring`` (Python 3.12+,
  near-zero overhead: each line's event is disabled after its first hit)
  or a ``sys.settrace`` fallback on older interpreters, installed before
  pytest imports the package so module/class bodies count.

The two mechanisms agree because both see CPython line events for the
same compiled code; the floor's one-point margin absorbs minor
``co_lines`` differences between interpreter versions.
"""

from __future__ import annotations

import argparse
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
SRC = os.path.join(REPO, "src", "repro")
# scored trees: the scheduler core and the contract analyzer that guards it
TARGETS = (
    os.path.join(SRC, "core"),
    os.path.join(SRC, "analysis"),
)


def executable_lines(path: str) -> set[int]:
    with open(path, "r") as fh:
        source = fh.read()
    lines: set[int] = set()
    stack = [compile(source, path, "exec")]
    while stack:
        code = stack.pop()
        lines.update(
            ln for _, _, ln in code.co_lines() if ln is not None
        )
        for const in code.co_consts:
            if hasattr(const, "co_lines"):
                stack.append(const)
    return lines


def install_tracer(hits: dict[str, set[int]]):
    """Record executed (file, line) pairs for files under TARGETS."""
    # co_filename may carry unnormalized components (e.g. the conftest's
    # ``tests/../src`` sys.path entry) — resolve once per distinct string
    resolved: dict[str, str | None] = {}

    def target_path(fname: str) -> str | None:
        out = resolved.get(fname, "")
        if out == "":
            norm = os.path.abspath(fname)
            out = norm if any(
                norm.startswith(t + os.sep) for t in TARGETS
            ) else None
            resolved[fname] = out
        return out

    if hasattr(sys, "monitoring"):  # Python 3.12+
        mon = sys.monitoring
        tool = mon.COVERAGE_ID
        mon.use_tool_id(tool, "core-coverage")

        def on_line(code, line):
            path = target_path(code.co_filename)
            if path is not None:
                hits.setdefault(path, set()).add(line)
            return mon.DISABLE  # first hit per line is all we need

        mon.register_callback(tool, mon.events.LINE, on_line)
        mon.set_events(tool, mon.events.LINE)
        return

    def local(frame, event, arg):
        if event == "line":
            path = target_path(frame.f_code.co_filename)
            if path is not None:
                hits.setdefault(path, set()).add(frame.f_lineno)
        return local

    def global_tracer(frame, event, arg):
        if target_path(frame.f_code.co_filename) is not None:
            return local
        return None

    sys.settrace(global_tracer)
    import threading

    threading.settrace(global_tracer)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fail-under", type=float, default=None,
                    help="exit non-zero when total coverage (%%) is lower")
    ap.add_argument("pytest_args", nargs="*",
                    help="arguments forwarded to pytest (after --)")
    args = ap.parse_args()

    sys.path.insert(0, os.path.join(REPO, "src"))
    hits: dict[str, set[int]] = {}
    install_tracer(hits)

    import pytest

    status = pytest.main(args.pytest_args or ["-q", "tests"])
    if hasattr(sys, "monitoring"):
        sys.monitoring.free_tool_id(sys.monitoring.COVERAGE_ID)
    else:
        sys.settrace(None)
    if status not in (0,):
        print(f"core_coverage: pytest exited {status}; not scoring")
        return int(status)

    rows = []
    tot_exec = tot_hit = 0
    for target in TARGETS:
        for dirpath, dirnames, filenames in os.walk(target):
            dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
            for name in sorted(filenames):
                if not name.endswith(".py"):
                    continue
                path = os.path.join(dirpath, name)
                rel = os.path.relpath(path, SRC).replace(os.sep, "/")
                exe = executable_lines(path)
                hit = hits.get(path, set()) & exe
                rows.append((rel, len(hit), len(exe)))
                tot_exec += len(exe)
                tot_hit += len(hit)

    width = max(len(n) for n, _, _ in rows)
    print(f"\n{'file':<{width}}  {'lines':>6}  {'hit':>6}  {'cover':>7}")
    for name, hit, exe in rows:
        pct = 100.0 * hit / exe if exe else 100.0
        print(f"{name:<{width}}  {exe:>6}  {hit:>6}  {pct:>6.1f}%")
    total = 100.0 * tot_hit / tot_exec if tot_exec else 100.0
    print(f"{'TOTAL':<{width}}  {tot_exec:>6}  {tot_hit:>6}  {total:>6.1f}%")

    if args.fail_under is not None and total < args.fail_under:
        print(f"core_coverage: {total:.1f}% is below the floor "
              f"{args.fail_under:.1f}%")
        return 2
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
