#!/usr/bin/env python
"""CI fault-injection matrix cell: one seeded closed-loop serving run
with every fault channel active, hard-asserting the fault-tolerance
invariants.

  PYTHONPATH=src:tests python tools/fault_matrix.py --seed 3 --fail-rate 0.02

Per cell this drives a three-device pool (A100 + 2x A30, the two A30s
sharing a correlated failure domain) through a Poisson deadline stream
under the deterministic injector (profile noise, stragglers, Poisson
task failures at ``--fail-rate``, device MTBF outages, correlated
domain shocks), with the hardened recovery layer armed — speculative
backup attempts plus per-task checkpoint credit — then checks:

* ``assert_fault_invariants`` — quarantine honoured (no placement inside
  an outage window, nothing spans a loss un-failed), retry backoff
  floors, no stranded withdrawals, backup-attempt exclusivity, and
  checkpoint-credit monotonicity;
* **correlated shocks** — every domain outage takes both members down
  (and back up) at the same seeded instants;
* **resolution coverage** — every submitted task ends completed,
  permanently failed, or explicitly rejected;
* **reproducibility** — a second run of the same cell produces the
  identical completion map AND the identical speculation/checkpoint
  event logs (the draws are pure functions of
  ``(seed, stream, task_id, attempt)``).

Exit code 0 = all invariants hold; any violation raises.
"""

import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

import numpy as np

from invariants import assert_fault_invariants
from repro.core import (
    A30,
    A100,
    FaultInjector,
    FaultSpec,
    RetryPolicy,
    SchedulerConfig,
    SchedulingService,
    SpeculationPolicy,
    cluster,
    run_with_faults,
)
from repro.core.synth import generate_tasks, workload


def run_cell(seed: int, fail_rate: float, n: int = 24):
    tasks = generate_tasks(n, A100, workload("mixed", "wide", A100),
                           seed=seed)
    tasks = [dataclasses.replace(t, checkpoint_period_s=2.0)
             for t in tasks]
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.2, size=n))
    stream = [(float(a), t, float(a) + 150.0)
              for a, t in zip(arrivals, tasks)]
    # devices 1 and 2 (the two A30s) share a rack-style failure domain
    fspec = FaultSpec(seed=seed, noise_sigma=0.08, straggler_prob=0.15,
                      straggler_factor=3.0, task_fail_rate=fail_rate,
                      device_mtbf_s=80.0, device_repair_s=25.0,
                      domains=((1, 2),), domain_mtbf_s=90.0,
                      domain_repair_s=20.0)

    def one_run():
        svc = SchedulingService(
            pool=cluster(A100, A30, A30),
            config=SchedulerConfig(
                max_wait_s=5.0, max_batch=8, min_batch=2, replan=True,
                straggler_factor=2.5,
                retry=RetryPolicy(max_attempts=3, backoff_base=0.5),
                speculation=SpeculationPolicy(),
            ),
        )
        rep = run_with_faults(svc, stream, injector=FaultInjector(fspec))
        return svc, rep

    svc, rep = one_run()
    assert_fault_invariants(svc)
    resolved = (set(rep.completions) | set(rep.failed)
                | set(svc.stats.rejected))
    missing = {t.id for t in tasks} - resolved
    assert not missing, f"stranded tasks: {sorted(missing)}"
    # correlated shocks: at every seeded domain-shock instant BOTH
    # members must be dark — either freshly quarantined by the shock or
    # already inside an overlapping independent device-MTBF window
    domain = (1, 2)
    horizon = max(a for a, _, _ in stream) + 10.0 * 5.0 + 100.0
    shocks = FaultInjector(fspec).domain_outages(0, horizon)
    for t_lost, _rec in shocks:
        for dev in domain:
            dark = any(
                ev.device == dev and ev.lost_at <= t_lost + 1e-9
                and (ev.recovered_at is None
                     or ev.recovered_at >= t_lost - 1e-9)
                for ev in svc.stats.outages)
            assert dark, (
                f"domain shock at t={t_lost}: member device {dev} "
                f"was not dark")
    svc2, rep2 = one_run()
    assert rep.completions == rep2.completions, "run is not reproducible"
    assert rep.failed == rep2.failed
    assert svc.stats.speculations == svc2.stats.speculations, \
        "speculation log is not reproducible"
    assert svc.stats.checkpoints == svc2.stats.checkpoints, \
        "checkpoint log is not reproducible"
    return svc, rep, len(shocks)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--seed", type=int, required=True)
    ap.add_argument("--fail-rate", type=float, required=True)
    ap.add_argument("--n", type=int, default=24)
    args = ap.parse_args()
    svc, rep, domain_shocks = run_cell(args.seed, args.fail_rate, args.n)
    spec_wins = sum(1 for ev in svc.stats.speculations
                    if ev.winner == "backup")
    print(f"seed={args.seed} fail_rate={args.fail_rate}: "
          f"{len(rep.completions)} completed, {len(rep.failed)} failed, "
          f"{len(svc.stats.rejected)} rejected, "
          f"{svc.stats.stragglers} stragglers, "
          f"{len(svc.stats.outages)} outages "
          f"({domain_shocks} correlated shocks), "
          f"{len(svc.stats.retries)} retries, "
          f"{len(svc.stats.speculations)} speculations "
          f"({spec_wins} backup wins), "
          f"{len(svc.stats.checkpoints)} checkpoints — invariants OK")


if __name__ == "__main__":
    main()
